"""Mesh-serving benchmark: tensor-parallel decode throughput per device
count, plus data-parallel replica routing, on a simulated host mesh.

Device count is fixed at the first backend initialization, so this
module force-creates its simulated devices *before importing jax*
(``JAX_PLATFORMS=cpu`` + ``--xla_force_host_platform_device_count=8``)
and therefore must run in its own process —
``benchmarks/serve_engine.py`` invokes it via ``subprocess`` and folds
the result into ``BENCH_serve.json`` as the ``mesh`` trajectory.

Per tp in {1, 2, 4, 8}: one engine on a ``(1, tp)`` device slice with
the KV pools sharded over the ``model`` axis (``PagedKVCache``'s paged
layout), serving the identical uniform trace. Token streams — greedy
*and* seeded-sampled — are asserted bit-identical to the tp=1 engine's
(``streams_equal``); the per-tp rows track decode tok/s so the
trajectory shows how sharded decode scales with device count. A
2-replica ``ReplicaRouter`` run rides along for the data-parallel path,
stream-checked against the same oracle.

Simulated CPU devices share one host, so tok/s here measures sharding
*overhead*, not speedup — the number to watch is how far below the
tp=1 row the tp=8 row sits, and that streams stay equal.

Progress goes to stderr; the final line on stdout is the JSON payload.

  python -m benchmarks.serve_mesh [--smoke]
"""

from __future__ import annotations

import os


def _force_host_devices(n: int = 8) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


_force_host_devices()  # must precede the jax import

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.serving import Engine, EngineConfig, SamplingParams  # noqa: E402
from repro.serving.router import ReplicaRouter  # noqa: E402

ARCH = "qwen3-1.7b"


def _log(msg: str) -> None:
    print(f"serve_mesh: {msg}", file=sys.stderr, flush=True)


def _sub_mesh(tp: int) -> Mesh:
    sub = np.asarray(jax.devices()[:tp]).reshape(1, tp)
    return Mesh(sub, ("data", "model"))


def _serve(eng, prompts, gen: int, sampled: bool) -> dict[int, list[int]]:
    for i, p in enumerate(prompts):
        sp = (
            SamplingParams(temperature=0.8, top_k=40, seed=100 + i)
            if sampled
            else None
        )
        eng.submit(p, gen, sampling=sp)
    fins = eng.drain()
    # uid counters run across serves; key by submit order so streams
    # from different engines/passes compare directly
    ordered = sorted(fins, key=lambda f: f.uid)
    return {i: f.tokens.tolist() for i, f in enumerate(ordered)}


def _measure_tp(tp: int, cfg, prompts, gen: int, repeats: int):
    """One engine on a (1, tp) slice: serve the trace greedy and
    sampled (first pass warms each program set), then time greedy
    repeats and keep the best decode tok/s."""
    eng = Engine(
        cfg,
        _sub_mesh(tp),
        engine_cfg=EngineConfig(
            max_slots=len(prompts), max_len=len(prompts[0]) + gen + 1
        ),
        strategy="tp",
        seed=0,
    )
    greedy = _serve(eng, prompts, gen, sampled=False)  # warms greedy jits
    sampled = _serve(eng, prompts, gen, sampled=True)  # warms sampled jits
    best = None
    for _ in range(repeats):
        eng.reset_stats()
        t0 = time.perf_counter()
        fins = _serve(eng, prompts, gen, sampled=False)
        wall = time.perf_counter() - t0
        out = eng.stats_summary()
        out["wall_tok_s"] = round(
            sum(len(t) for t in fins.values()) / wall, 2
        )
        out["wall_s"] = round(wall, 4)
        if best is None or out["decode_tok_s"] > best["decode_tok_s"]:
            best = out
    row = {
        "devices": tp,
        "paged_impl": eng.paged_impl,
        "decode_tok_s": best["decode_tok_s"],
        "wall_tok_s": best["wall_tok_s"],
        "wall_s": best["wall_s"],
        "p95_token_latency_ms": best["p95_token_latency_ms"],
    }
    return row, greedy, sampled


def _measure_router(cfg, prompts, gen: int, oracle: dict) -> dict:
    """2-replica data-parallel routing (tp=1 per replica): router uids
    follow submit order, so streams must equal the single engine's."""
    router = ReplicaRouter(
        cfg,
        replicas=2,
        tp=1,
        engine_cfg=EngineConfig(
            max_slots=len(prompts), max_len=len(prompts[0]) + gen + 1
        ),
        seed=0,
    )
    _serve(router, prompts, gen, sampled=True)  # warm both replicas
    t0 = time.perf_counter()
    streams = _serve(router, prompts, gen, sampled=True)
    wall = time.perf_counter() - t0
    equal = streams == oracle
    assert equal, "replica routing changed token streams"
    return {
        "replicas": 2,
        "tp": 1,
        "wall_s": round(wall, 4),
        "wall_tok_s": round(
            sum(len(t) for t in streams.values()) / wall, 2
        ),
        "streams_equal": equal,
        "per_replica_finished": [
            int(e.stats.finished) for e in router.engines
        ],
    }


def run(smoke: bool = False) -> dict:
    cfg = registry.get_smoke(ARCH, sparse=True)
    batch, prompt_len, gen, repeats = 4, 32, 16, 2
    if smoke:
        cfg = cfg.replace(num_layers=2, vocab_size=256)
        batch, prompt_len, gen, repeats = 2, 8, 4, 1
    n_dev = len(jax.devices())
    tps = [t for t in (1, 2, 4, 8) if t <= n_dev]
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
        for _ in range(batch)
    ]

    by_tp, oracle_g, oracle_s = {}, None, None
    equal = True
    for tp in tps:
        _log(f"tp={tp} ({n_dev} devices, smoke={smoke})")
        row, greedy, sampled = _measure_tp(tp, cfg, prompts, gen, repeats)
        if tp == 1:
            oracle_g, oracle_s = greedy, sampled
        else:
            ok = greedy == oracle_g and sampled == oracle_s
            equal = equal and ok
            assert ok, f"tp={tp} streams diverged from single-device oracle"
        by_tp[str(tp)] = row
    _log("router replicas=2")
    router = _measure_router(cfg, prompts, gen, oracle_s)

    payload = {
        "smoke": smoke,
        "devices": n_dev,
        "platform": jax.devices()[0].platform,
        "batch": batch,
        "prompt_len": prompt_len,
        "gen": gen,
        "streams_equal": equal,
        "by_tp": by_tp,
        "router": router,
    }
    print(json.dumps(payload), flush=True)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale dry run (tier-1 gate)")
    run(smoke=ap.parse_args().smoke)
