"""Paper Fig 8 / Table 5: GPT-2-class LM, dense vs Pixelfly.

CPU-scale twin of the WikiText-103 table: reduced GPT-2-small-family
config; measures train-step wall-clock, parameter ratio, and loss parity
after a fixed number of steps on the synthetic LM stream (the paper's
claim is iso-perplexity at 2.1x faster training).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_local_mesh
from repro.training.data import SyntheticLM
from repro.training.loop import TrainConfig, Trainer
from repro.training.optimizer import OptConfig


def _cfg(sparse: bool) -> ModelConfig:
    return ModelConfig(
        name="gpt2-bench", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=1024,
        vocab_size=512, dtype="float32", sparse=sparse,
        sparse_density=0.2, sparse_block=64, attn_block=64, attn_chunk=128,
        sparse_attention=sparse,
    )


def run(steps: int = 25) -> None:
    results = {}
    for sparse in (False, True):
        cfg = _cfg(sparse)
        data = SyntheticLM(cfg.vocab_size, 128, 8, seed=0)
        tr = Trainer(
            cfg,
            OptConfig(lr=3e-3, warmup_steps=2, total_steps=steps),
            data,
            make_local_mesh(),
            TrainConfig(
                steps=steps, ckpt_dir=f"/tmp/bench_lm_{sparse}",
                ckpt_every=10_000, log_every=10_000,
            ),
        )
        hist = tr.run()
        med = sorted(h["step_time_s"] for h in hist[2:])[len(hist[2:]) // 2]
        n_params = sum(p.size for p in jax.tree.leaves(tr.state["params"]))
        results[sparse] = {
            "us": med * 1e6,
            "loss": float(np.mean([h["loss"] for h in hist[-5:]])),
            "params": n_params,
        }
    d, s = results[False], results[True]
    emit(
        "lm_speedup/gpt2-class",
        s["us"],
        f"dense_us={d['us']:.0f};speedup={d['us']/s['us']:.2f}x"
        f";loss_sparse={s['loss']:.3f};loss_dense={d['loss']:.3f}"
        f";param_ratio={s['params']/d['params']:.3f}",
    )


if __name__ == "__main__":
    run()
