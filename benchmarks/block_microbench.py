"""Paper App. L.5 / Table 7: block-size microbenchmark.

For a 4K x 4K matrix: expected density vs *actual* density (fraction of
elements a block-b device must touch = the (b,b)-block cover), for random
vs pixelfly patterns, plus measured latency of the corresponding gather
GEMM. Reproduces the paper's headline: ~1% random sparsity touches ~100%
of the matrix on a block device; pixelfly's block-aligned pattern touches
exactly what it uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import butterfly as bf


def run(n: int = 4096, hw_block: int = 32, batch: int = 256) -> None:
    rng = np.random.default_rng(0)

    rows = []
    for blk in [1, 2, 4, 8, 16, 32]:
        density = 0.0125 * blk if blk < 16 else 0.10
        # random pattern grouped into blk x blk blocks
        nb = n // blk
        keep = rng.random((nb, nb)) < density
        mask = np.repeat(np.repeat(keep, blk, 0), blk, 1).astype(np.float32)
        actual = bf.block_cover_density(mask, hw_block)
        rows.append(("random", blk, density, actual))

    for blk in [4, 8, 16, 32]:
        pat = bf.make_pattern(n, n, block=blk, density=0.10)
        actual = bf.block_cover_density(pat.dense_mask(), hw_block)
        rows.append(("pixelfly", blk, pat.density, actual))

    # latency proxy: masked-dense (what a block device pays for misaligned
    # sparsity: compute over the block cover) vs BSR gather for pixelfly.
    x = jnp.asarray(rng.standard_normal((batch, n)), jnp.float32)
    pat = bf.make_pattern(n, n, block=hw_block, density=0.10)
    blocks = jnp.asarray(
        rng.standard_normal((pat.nb_out, pat.r, hw_block, hw_block)),
        jnp.float32,
    )
    from repro.kernels import ref

    t_bsr = time_fn(
        jax.jit(lambda x: ref.bsr_matmul_gather(x, blocks, jnp.asarray(pat.cols))), x
    )
    w = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    t_dense = time_fn(jax.jit(lambda x: x @ w), x)

    for kind, blk, exp, act in rows:
        emit(
            f"block_microbench/{kind}/b={blk}",
            0.0,
            f"expected_density={exp:.4f};actual_density={act:.4f}",
        )
    emit(
        "block_microbench/latency",
        t_bsr,
        f"dense_us={t_dense:.1f};bsr_speedup={t_dense / t_bsr:.2f}x"
        f";density={pat.density:.3f}",
    )


if __name__ == "__main__":
    run()
