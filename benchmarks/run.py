"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; suites with JSON artifacts
(``serve_engine`` -> BENCH_serve.json) write them under ``--json DIR``.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json DIR]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json",
        default=None,
        metavar="DIR",
        help="directory for JSON artifacts (e.g. BENCH_serve.json); "
        "suites that emit JSON write there instead of the cwd",
    )
    args = ap.parse_args()

    from benchmarks import (
        block_microbench,
        common,
        flat_vs_product,
        lm_speedup,
        lra_attention,
        ntk_distance,
        roofline_report,
        serve_engine,
        vision_speedup,
    )

    if args.json:
        common.set_json_dir(args.json)

    suites = {
        "flat_vs_product": flat_vs_product.run,      # App. J / Fig 11
        "block_microbench": block_microbench.run,    # App. L.5 / Table 7
        "ntk_distance": ntk_distance.run,            # Fig 4
        "vision_speedup": vision_speedup.run,        # Fig 5 / Table 4
        "lm_speedup": lm_speedup.run,                # Fig 8 / Table 5
        "lra_attention": lra_attention.run,          # Fig 9 (LRA)
        "roofline": roofline_report.run,             # §Roofline
        "serve_engine": serve_engine.run,            # BENCH_serve.json
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
