"""Paper Fig 4: empirical-NTK distance to the dense model.

Computes the empirical NTK (K_ij = <df(x_i)/dtheta, df(x_j)/dtheta>) of a
small MLP under different weight masks at equal density and reports
||K_mask - K_dense||_F / ||K_dense||_F. The paper's finding: the flat
block butterfly + low-rank pattern is closest to dense — the selection
principle behind Pixelfly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import butterfly as bf

D, H, N = 64, 256, 24  # input dim, hidden, #NTK samples
BLOCK = 8


def _masked_mlp(mask1, mask2):
    def f(params, x):
        w1 = params["w1"] * mask1
        w2 = params["w2"] * mask2
        h = jax.nn.relu(x @ w1)
        return (h @ w2).squeeze(-1)

    return f


def _ntk(f, params, xs):
    def g(x):
        grads = jax.grad(lambda p: f(p, x[None]).sum())(params)
        return jnp.concatenate([v.ravel() for v in jax.tree.leaves(grads)])

    G = jax.vmap(g)(xs)  # (N, P)
    return G @ G.T


def _lowrank_mask(rows, cols, rank):
    m = np.zeros((rows, cols), np.float32)
    m[:rank, :] = 1.0
    m[:, :rank] = 1.0
    return m


def run(seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
    params = {
        "w1": jnp.asarray(rng.standard_normal((D, H)) / np.sqrt(D), jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((H, 1)) / np.sqrt(H), jnp.float32),
    }
    ones2 = np.ones((H, 1), np.float32)

    dense_mask = np.ones((D, H), np.float32)
    k_dense = _ntk(_masked_mlp(jnp.asarray(dense_mask), jnp.asarray(ones2)), params, xs)
    nd = float(jnp.linalg.norm(k_dense))

    # candidate masks at (approximately) equal density
    pat = bf.make_pattern(H, D, block=BLOCK, max_stride=4)
    butterfly_mask = pat.dense_mask().T  # (D, H)
    density = butterfly_mask.mean()

    rank = max(1, int(density * D * H / (D + H) / 2))
    global_mask = _lowrank_mask(D, H, rank)
    # pixelfly = 3/4 butterfly + 1/4 low-rank budget
    pat_s = bf.make_pattern(H, D, block=BLOCK, max_stride=2)
    pf = np.clip(pat_s.dense_mask().T + _lowrank_mask(D, H, max(1, rank // 2)), 0, 1)
    rand_mask = (rng.random((D, H)) < density).astype(np.float32)

    cands = {
        "pixelfly(butterfly+lowrank)": pf,
        "butterfly_only": butterfly_mask,
        "lowrank_only(global)": global_mask,
        "random(magnitude-init)": rand_mask,
    }
    out = {}
    for name, m in cands.items():
        k = _ntk(_masked_mlp(jnp.asarray(m), jnp.asarray(ones2)), params, xs)
        out[name] = float(jnp.linalg.norm(k - k_dense)) / nd
    best = min(out, key=out.get)
    for name, v in sorted(out.items(), key=lambda kv: kv[1]):
        emit(
            f"ntk_distance/{name}",
            0.0,
            f"rel_ntk_dist={v:.4f};density={cands[name].mean():.3f}"
            + (";closest_to_dense" if name == best else ""),
        )


if __name__ == "__main__":
    run()
