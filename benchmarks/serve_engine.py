"""Serving benchmark: continuous-batching engine vs the fixed-batch Server.

Three measurements on the same smoke config and shared weights:

1. **uniform** — the exact workload the seed ``Server`` can run (one
   fixed-size batch, equal prompt/gen lengths) on both paths. The engine
   wins on prefill alone: one jit'd bucketed pass vs a per-token python
   loop through the decode step.
2. **mixed** — what only the engine can do: ragged prompt/gen lengths,
   twice as many requests as slots, late arrivals submitted mid-flight.
   Continuous batching shows up in the occupancy stats (slots refill the
   step after an eviction).
3. **prefill-heavy** — many short ragged requests with tiny gen lengths,
   where admission dominates: batched bucketed prefill (one jit'd call +
   one host sync per same-bucket group) vs the per-request-admission
   baseline (``max_prefill_batch=1``) on the identical trace. Both
   engines are warmed up front and each repeat measures the two modes
   back-to-back; the committed speedup is the median *paired* ratio,
   so a patch of machine load hits both legs of a pair instead of
   skewing whichever mode's block it landed in.
4. **decode-by-sampler** — the uniform workload served greedy vs fully
   sampled (temperature + top-k + top-p + repetition penalty, seeded per
   request). Sampling is fused into the jit'd decode step, so sampled
   decode tok/s should sit within ~10% of greedy.
5. **prefix-cache** — a shared-system-prompt trace (every request = one
   long common prefix + a short unique tail, tiny gens: admission/TTFT
   dominates) served with the radix-tree prefix cache on vs off on
   identical engines. Cache-on admissions map the shared prefix pages
   straight into the slot and prefill only the suffix, so
   ``admission_speedup`` (prefill seconds, off/on) is the headline
   number; token streams are asserted identical either way.
6. **goodput** — SLO-aware scheduling under seeded traffic
   (``repro.serving.workloads``): a *burst* trace (deadline'd
   high-priority burst landing on a pool full of long background
   decodes) and a *long-tail* trace (open-loop Poisson arrivals, an
   interactive deadline'd tier over a heavy batch tail), each served
   with preemption on vs off on the same seed. The headline is SLO
   attainment: with preemption the burst swaps the background out to
   host memory (``repro.serving.swap``) and meets its deadlines;
   without, it queues behind the slots and misses them. Token streams
   are asserted bit-identical across modes — preemption is a pure
   scheduling change. A *chat* trace (multi-turn conversations, prefix
   cache on) rides along to measure turn-2+ admissions hitting the
   decode-written pages the engine indexes at finish.
7. **observability** — tracer overhead: the uniform workload on
   identical warm engines with span tracing on vs off, measured as
   paired repeats (median traced/off decode-tok/s ratio). Tracing must
   stay near-free (~2% budget at production scale; the smoke floor is
   looser because microsecond steps amplify scheduler jitter) and must
   not change a single token. ``--trace-out`` exports the traced ring
   as Perfetto JSON, which tier 1 round-trips through the validator.
8. **observability_live** — the full live-telemetry plane (rolling
   windows, burn-rate SLO monitor, per-step memory gauges) on vs off,
   same paired-repeat protocol as scenario 7 with a committed 0.95
   monitored/off decode floor, streams bit-identical. ``--listen``
   additionally scrapes ``/metrics`` + ``/healthz`` *mid-decode* and
   asserts the ``/vars`` windowed percentiles agree with the final
   ``stats_summary()``. A *slo_shed* sub-scenario overloads a
   no-preemption engine with long low-priority decodes against a
   deadline'd high-priority stream: with ``SloConfig(shed=True)`` the
   CRITICAL burn state drops the queued background as structured
   ``REJECT_SHED`` rejections and high-priority SLO attainment must be
   strictly higher than with shedding off.
9. **mesh** — tensor-parallel decode on a simulated 8-device host mesh
   plus 2-replica data-parallel routing, via ``benchmarks.serve_mesh``
   in a subprocess (the simulated devices must be forced before jax
   initializes a backend, which this process has already done). Tracks
   decode tok/s per device count and asserts greedy and sampled streams
   bit-identical to the single-device engine's.

Every (N, S) prefill bucket a timed trace will hit is compiled *before*
the clock starts (``_warm_buckets``), so latency percentiles measure
steady-state serving, not JIT.

Emits one CSV row per scenario and writes ``BENCH_serve.json`` (under
``--json DIR`` when invoked via ``benchmarks.run``).

``--smoke`` shrinks the model and every trace to a seconds-scale dry
run of every scenario (JSON goes to a temp dir, never clobbering the
tracked ``BENCH_serve.json``) — ``scripts/tier1.sh`` runs it so
benchmark-script breakage fails tier 1 instead of rotting silently.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Server
from repro.serving import Engine, EngineConfig, SamplingParams, ServeStats
from repro.serving import workloads

ARCH = "qwen3-1.7b"
BATCH = 4
PROMPT_LEN = 32
GEN = 16
# the fully-loaded sampled scenario (every filter live)
SAMPLED = SamplingParams(
    temperature=0.8, top_k=40, top_p=0.95, repetition_penalty=1.1
)


def _warm_buckets(
    engine: Engine,
    lens: list[int],
    sampling: SamplingParams | None = None,
) -> None:
    """Compile every prefill program a trace can reach before timing: for
    each S bucket the lens map to, drive one admission group at every
    power-of-two batch size up to ``max_prefill_batch`` (plus the decode
    program via drain). ``sampling`` warms the same buckets' *sampled*
    program variants instead of the plain ones. Resets the engine's
    stats afterwards."""
    vocab = engine.cfg.vocab_size
    rng = np.random.default_rng(4321)
    nvals, n = {1}, 1
    while 2 * n <= engine.ecfg.max_prefill_batch:
        n *= 2
        nvals.add(n)
    for s in sorted({engine._bucket(ln) for ln in lens}):
        # -1: a full-slot prompt would capacity-finish straight after
        # prefill, and the warm drain would never touch the decode path
        plen = min(s, engine.ecfg.max_len) - 1
        for n in sorted(nvals):
            for _ in range(n):
                engine.submit(
                    rng.integers(0, vocab, plen).astype(np.int32), 2,
                    sampling=sampling,
                )
            engine.drain()
    engine.reset_stats()


def _measure_uniform(
    engine: Engine,
    prompts: np.ndarray,
    gen: int,
    sampling: SamplingParams | None = None,
    repeats: int = 3,
) -> dict:
    """Warm the jits, then serve the uniform wave ``repeats`` times and
    keep the best run by decode tok/s (every program is warm, so repeats
    are i.i.d. — best-of shields the scenario from load noise).
    ``sampling``: per-request params (request b gets seed+b); None keeps
    the greedy default."""
    _warm_buckets(engine, [prompts.shape[1]], sampling)
    best: dict | None = None
    for _ in range(repeats):
        engine.reset_stats()
        t0 = time.perf_counter()
        for b in range(prompts.shape[0]):
            engine.submit(
                prompts[b],
                gen,
                sampling=None
                if sampling is None
                else dataclasses.replace(sampling, seed=sampling.seed + b),
            )
        finished = engine.drain()
        wall_s = time.perf_counter() - t0
        out = engine.stats_summary()
        tokens = sum(len(f.tokens) for f in finished)
        out["wall_tok_s"] = round(tokens / wall_s, 2)
        out["wall_s"] = round(wall_s, 4)
        if best is None or out["decode_tok_s"] > best["decode_tok_s"]:
            best = out
    return best


def _measure_guarded(
    engine: Engine, prompts: np.ndarray, gen: int, *, enforce: bool
) -> dict:
    """One uniform wave with the steady-state decode loop inside a
    DispatchGuard: proves (``enforce=True``, raising — the tier-1 /
    --guards mode) or records (``enforce=False``, counting) that decode
    performs zero recompiles and zero implicit device→host transfers
    per step after warmup, with exactly one explicit batched fetch (the
    next-token row) per step."""
    from repro.analysis.guards import DispatchGuard

    for b in range(prompts.shape[0]):
        engine.submit(prompts[b], gen)
    engine.step()  # warmup step: admission prefill + first decode
    # Guard the steady-state middle only: requests finishing free their
    # slots, and the resulting re-bucketing is warmup work by contract,
    # not a per-step cost.
    steps = max(gen - 2, 1)
    guard = DispatchGuard(
        max_compiles=0 if enforce else None,
        raise_on_sync=enforce,
    )
    with guard:
        for _ in range(steps):
            engine.step()
    engine.drain(max_steps=64 * max(gen, 1))
    return {
        "steps": steps,
        "compiles": guard.compiles,
        "implicit_d2h": guard.implicit_syncs,
        "explicit_syncs": guard.explicit_syncs,
        "enforced": enforce,
        "clean": guard.compiles == 0 and guard.implicit_syncs == 0,
    }


def _measure_trace(
    engine: Engine,
    prompts: list[np.ndarray],
    gens: list[int],
    repeats: int = 3,
) -> dict:
    """Submit a whole trace, drain, fold wall-clock into the stats.
    Best-of-``repeats`` (every program is pre-warmed, so repeats are
    i.i.d.): shields the admission-path comparison from load noise."""
    best: dict | None = None
    for _ in range(repeats):
        engine.reset_stats()
        t0 = time.perf_counter()
        for p, g in zip(prompts, gens):
            engine.submit(p, g)
        finished = engine.drain()
        wall_s = time.perf_counter() - t0
        out = engine.stats_summary()
        out["wall_tok_s"] = round(
            sum(len(f.tokens) for f in finished) / wall_s, 2
        )
        out["wall_s"] = round(wall_s, 4)
        out["requests"] = len(prompts)
        if best is None or out["wall_tok_s"] > best["wall_tok_s"]:
            best = out
    return best


def _measure_prefix_cache(
    cfg, mesh, params, batch: int, smoke: bool, repeats: int
) -> dict:
    """Shared-system-prompt scenario: prefix cache on vs off.

    Both engines serve the identical trace with identical geometry; the
    warmup pass compiles every program *and* (cache-on) populates the
    radix tree, so the measured repeats see steady-state hit rates —
    exactly what a production system serving one system prompt to a
    stream of users looks like. Best-of-``repeats`` by admission time
    (prefill seconds)."""
    page = cfg.attn_block
    sys_pages = 3
    max_len = (sys_pages + 2) * page
    n_req = (2 if smoke else 4) * batch
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(
        0, cfg.vocab_size, sys_pages * page, dtype=np.int32
    )
    prompts = [
        np.concatenate(
            [
                sys_prompt,
                rng.integers(
                    0,
                    cfg.vocab_size,
                    int(rng.integers(4, page // 2)),
                    dtype=np.int32,
                ),
            ]
        )
        for _ in range(n_req)
    ]
    gens = [int(rng.integers(2, 5)) for _ in range(n_req)]

    results, streams = {}, {}
    for mode, on in (("on", True), ("off", False)):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=batch, max_len=max_len, prefix_cache=on
            ),
            params=params,
        )
        for p, g in zip(prompts, gens):  # warm every program (+ the tree)
            eng.submit(p, g)
        eng.drain()
        best = None
        for _ in range(repeats):
            eng.reset_stats()
            t0 = time.perf_counter()
            for p, g in zip(prompts, gens):
                eng.submit(p, g)
            fins = eng.drain()
            wall = time.perf_counter() - t0
            out = eng.stats_summary()
            out["wall_s"] = round(wall, 4)
            out["wall_tok_s"] = round(
                sum(len(f.tokens) for f in fins) / wall, 2
            )
            if best is None or out["prefill_s"] < best["prefill_s"]:
                best = out
                streams[mode] = {
                    f.uid - fins[0].uid: f.tokens.tolist()
                    for f in sorted(fins, key=lambda f: f.uid)
                }
        results[mode] = best
    # the cache must be a pure optimization: identical token streams
    assert streams["on"] == streams["off"], "prefix cache changed tokens"
    keys = (
        "prefill_s",
        "prefill_tokens",
        "wall_s",
        "wall_tok_s",
        "p95_token_latency_ms",
    )
    row = {m: {k: results[m][k] for k in keys} for m in ("on", "off")}
    pc = results["on"]["prefix_cache"]
    row["on"]["hit_rate"] = pc["hit_rate"]
    row["on"]["hit_tokens"] = pc["hit_tokens"]
    row["on"]["evicted_pages"] = pc["evicted_pages"]
    row["admission_speedup"] = round(
        results["off"]["prefill_s"]
        / max(results["on"]["prefill_s"], 1e-9),
        2,
    )
    row["wall_speedup"] = round(
        results["on"]["wall_tok_s"]
        / max(results["off"]["wall_tok_s"], 1e-9),
        2,
    )
    row["requests"] = n_req
    row["sys_prompt_tokens"] = sys_pages * page
    return row


def _goodput_pair(
    cfg,
    mesh,
    params,
    slots: int,
    max_len: int,
    items: list[workloads.WorkItem],
    *,
    strict: bool = False,
) -> dict:
    """Serve one seeded trace with preemption on vs off (identical
    engines otherwise) and fold each run into a goodput row.

    Calibration first: the "on" engine replays the trace once with
    deadlines unarmed — warming every program including the swap path —
    and its measured seconds-per-step converts the trace's
    step-denominated deadlines into wall-clock ``ScheduleParams``, the
    *same* values for both modes. Token streams are asserted
    bit-identical across modes (preemption must be a pure scheduling
    change); ``strict`` additionally asserts the trace preempted at
    least once and met strictly more deadlines with preemption on."""
    warm_lens = sorted({w.prompt.size for w in items})
    step_s = None
    out: dict = {}
    streams: dict[str, list] = {}
    for mode, on in (("on", True), ("off", False)):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=slots, max_len=max_len, preemption=on
            ),
            params=params,
        )
        _warm_buckets(eng, warm_lens)
        # first unarmed replay warms what _warm_buckets cannot reach
        # (the swap gather/scatter and presence-reseed programs fire on
        # the first preemption); the second measures steady-state
        # seconds-per-step for the deadline conversion
        workloads.replay(eng, items, step_s=None)
        if step_s is None:
            _, wall, steps = workloads.replay(eng, items, step_s=None)
            step_s = wall / max(steps, 1)
        eng.reset_stats()
        fins, wall, steps = workloads.replay(eng, items, step_s=step_s)
        row = workloads.goodput(fins, eng.stats_summary())
        row["wall_s"] = round(wall, 4)
        row["steps"] = steps
        out[mode] = row
        streams[mode] = [
            f.tokens.tolist() for f in sorted(fins, key=lambda f: f.uid)
        ]
    # preemption may only change WHEN things run, never WHAT they emit
    assert streams["on"] == streams["off"], (
        "preemption changed token streams"
    )
    out["attainment_gain"] = round(
        out["on"]["slo_attainment"] - out["off"]["slo_attainment"], 4
    )
    if strict:
        assert out["on"]["preemptions"] > 0, "trace never preempted"
        assert (
            out["on"]["slo_attainment"] > out["off"]["slo_attainment"]
        ), (
            f"preemption did not raise SLO attainment: "
            f"on={out['on']['slo_attainment']} "
            f"off={out['off']['slo_attainment']}"
        )
    return out


def _measure_observability(
    cfg,
    mesh,
    params,
    batch: int,
    prompt_len: int,
    gen: int,
    repeats: int,
    trace_out: str | None = None,
) -> dict:
    """Tracer overhead: the uniform workload on two otherwise-identical
    warm engines, tracing on vs off, measured back-to-back per repeat.
    The committed number is the median *paired* decode-tok/s ratio
    (traced / off), same protocol as prefill-heavy: load noise lands on
    both legs of a pair.  The tracer budget is ~2% steady-state; the
    hard floor here is loose (ratio >= 0.80) because smoke-scale decode
    steps are microseconds and scheduler jitter dominates.  Token
    streams must be bit-identical — tracing is observation only.
    ``trace_out``: export the traced engine's final ring there."""
    max_len = prompt_len + gen + 1
    engines = {}
    for mode, on in (("traced", True), ("off", False)):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=batch, max_len=max_len, trace=on
            ),
            params=params,
        )
        _warm_buckets(eng, [prompt_len])
        engines[mode] = eng
    rng = np.random.default_rng(7)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(batch, prompt_len), dtype=np.int32
    )
    pairs, streams = [], {}
    for _ in range(repeats):
        pair = {}
        for mode, eng in engines.items():
            eng.reset_stats()
            t0 = time.perf_counter()
            for b in range(batch):
                eng.submit(prompts[b], gen)
            fins = eng.drain()
            wall = time.perf_counter() - t0
            out = eng.stats_summary()
            out["wall_s"] = round(wall, 4)
            pair[mode] = out
            streams[mode] = [
                f.tokens.tolist() for f in sorted(fins, key=lambda f: f.uid)
            ]
        assert streams["traced"] == streams["off"], (
            "tracing changed token streams"
        )
        pairs.append(pair)
    ratios = [
        p["traced"]["decode_tok_s"] / max(p["off"]["decode_tok_s"], 1e-9)
        for p in pairs
    ]
    med_i = int(np.argsort(ratios)[len(ratios) // 2])
    ratio = round(sorted(ratios)[len(ratios) // 2], 4)
    assert ratio >= 0.80, (
        f"tracer overhead blew the budget: traced/off decode ratio "
        f"{ratio} (floor 0.80)"
    )
    keys = ("decode_tok_s", "p95_token_latency_ms", "wall_s")
    row = {
        m: {k: pairs[med_i][m][k] for k in keys} for m in ("traced", "off")
    }
    row["traced_vs_off"] = ratio
    row["overhead_pct"] = round((1.0 - ratio) * 100.0, 2)
    row["events_recorded"] = int(engines["traced"].tracer.n_recorded)
    if trace_out:
        row["trace_events_written"] = engines["traced"].export_perfetto(
            trace_out
        )
    return row


def _measure_observability_live(
    cfg,
    mesh,
    params,
    batch: int,
    prompt_len: int,
    gen: int,
    repeats: int,
    smoke: bool,
    listen: str | None = None,
) -> dict:
    """Live-plane overhead + mid-run scrape round-trip.

    The monitored engine carries the whole plane — rolling windows, the
    burn-rate monitor (shed disabled) and per-step memory gauges — vs a
    bare engine, measured as paired repeats (median monitored/off
    decode-tok/s ratio, same protocol as the tracer scenario). The
    committed (non-smoke) floor is 0.95: one window tick + a burn
    evaluation per step must stay inside 5%; the smoke floor is looser
    because microsecond steps amplify scheduler jitter. Token streams
    must be bit-identical — monitoring alone never changes what is
    served.

    With ``listen`` set, one extra monitored run scrapes ``/metrics``
    and ``/healthz`` *mid-decode* (round-tripping ``obs/prom.parse``)
    and asserts the end-of-run ``/vars`` windowed percentiles agree
    with ``stats_summary()`` — the window covers the whole run, so the
    raw-sample percentiles must match to exposition rounding."""
    from repro.obs import SloConfig

    max_len = prompt_len + gen + 1
    monitored_cfg = EngineConfig(
        max_slots=batch,
        max_len=max_len,
        monitor=True,
        slo=SloConfig(
            target=0.99, fast_window_s=5.0, slow_window_s=30.0
        ),
    )
    engines = {}
    for mode, ecfg in (
        ("monitored", monitored_cfg),
        ("off", EngineConfig(max_slots=batch, max_len=max_len)),
    ):
        eng = Engine(cfg, mesh, engine_cfg=ecfg, params=params)
        _warm_buckets(eng, [prompt_len])
        engines[mode] = eng
    rng = np.random.default_rng(17)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(batch, prompt_len), dtype=np.int32
    )
    pairs, streams = [], {}
    for _ in range(repeats):
        pair = {}
        for mode, eng in engines.items():
            eng.reset_stats()
            t0 = time.perf_counter()
            for b in range(batch):
                eng.submit(prompts[b], gen)
            fins = eng.drain()
            wall = time.perf_counter() - t0
            out = eng.stats_summary()
            out["wall_s"] = round(wall, 4)
            pair[mode] = out
            streams[mode] = [
                f.tokens.tolist()
                for f in sorted(fins, key=lambda f: f.uid)
            ]
        assert streams["monitored"] == streams["off"], (
            "live monitoring changed token streams"
        )
        pairs.append(pair)
    ratios = [
        p["monitored"]["decode_tok_s"]
        / max(p["off"]["decode_tok_s"], 1e-9)
        for p in pairs
    ]
    med_i = int(np.argsort(ratios)[len(ratios) // 2])
    ratio = round(sorted(ratios)[len(ratios) // 2], 4)
    floor = 0.80 if smoke else 0.95
    assert ratio >= floor, (
        f"live monitoring blew the budget: monitored/off decode ratio "
        f"{ratio} (floor {floor})"
    )
    keys = ("decode_tok_s", "p95_token_latency_ms", "wall_s")
    row = {
        m: {k: pairs[med_i][m][k] for k in keys}
        for m in ("monitored", "off")
    }
    row["monitored_vs_off"] = ratio
    row["overhead_pct"] = round((1.0 - ratio) * 100.0, 2)

    if listen:
        import json as _json
        import urllib.request

        from repro.obs.http import attach
        from repro.obs.prom import parse as prom_parse

        def _get(url: str) -> str:
            with urllib.request.urlopen(url, timeout=10.0) as r:
                assert r.status == 200, f"{url} -> {r.status}"
                return r.read().decode()

        eng = engines["monitored"]
        srv = attach(eng, listen)
        try:
            eng.reset_stats()
            for b in range(batch):
                eng.submit(prompts[b], gen)
            scraped = None
            step = 0
            while not eng.scheduler.idle or eng._rejected:
                fins = eng.step()
                step += 1
                if step == max(gen // 2, 1):  # scrape mid-decode
                    flat = prom_parse(_get(srv.url + "/metrics"))
                    assert (
                        flat["repro_serve_decode_steps_total"] > 0
                    ), "mid-run exposition missing decode steps"
                    assert _get(srv.url + "/healthz") == "ok\n"
                    scraped = len(flat)
            assert scraped is not None, "run too short to scrape"
            live = _json.loads(_get(srv.url + "/vars"))
            s = eng.stats_summary()
            # the window spans the whole (post-reset) run: /vars raw-
            # sample percentiles must agree with the final summary
            for vk, sk in (
                ("p50_ms", "p50_token_latency_ms"),
                ("p95_ms", "p95_token_latency_ms"),
            ):
                got = live["token_latency_ms"][vk]
                want = s[sk]
                assert abs(got - want) <= max(0.02, 0.01 * want), (
                    f"/vars {vk}={got} disagrees with "
                    f"stats_summary {sk}={want}"
                )
            slo = _json.loads(_get(srv.url + "/slo"))
            assert slo["enabled"] and slo["state"] == "OK"
            row["live_scrape"] = {
                "listen": srv.url,
                "midrun_metric_samples": scraped,
                "vars_token_p50_ms": live["token_latency_ms"]["p50_ms"],
                "summary_token_p50_ms": s["p50_token_latency_ms"],
                "pool_pages": live["memory"]["pool_pages"],
            }
        finally:
            srv.stop()
    return row


def _measure_slo_shed(cfg, mesh, params, slots: int) -> dict:
    """Burn-rate load shed under overload: shed on vs off.

    Preemption is OFF, so the only defense is the queue. A wave of
    long-decode low-priority requests pins every slot (and keeps a deep
    backlog to re-pin any slot that frees), while short deadline'd
    high-priority requests arrive on a steady clock. Without shedding,
    each freed slot is immediately re-pinned by backlog, so the
    interactive tier keeps queueing behind ~full decodes and misses.
    With ``SloConfig(shed=True)`` the first misses drive the monitor
    CRITICAL, the queued background is dropped as structured
    ``REJECT_SHED`` results, and later arrivals land on free slots.
    The headline assert: high-priority SLO attainment strictly higher
    with shedding on, and every shed surfaced as a structured
    rejection (never a silent drop)."""
    from repro.obs import SloConfig
    from repro.serving.request import REJECT_SHED

    page = cfg.attn_block
    max_len = 3 * page
    bg_gen = 2 * page - 1  # fills a slot end-to-end, no capacity finish
    n_bg = 4 * slots
    hi_gap, hi_dl = 6, 10
    # misses only surface when a late request *finishes* (first bg wave
    # boundary), so the interactive stream must outlive the background
    # horizon for the post-CRITICAL shed to protect later arrivals
    n_hi = ((n_bg // slots) * bg_gen) // hi_gap
    rng = np.random.default_rng(23)
    items = [
        workloads.WorkItem(
            arrival_step=0,
            prompt=rng.integers(1, cfg.vocab_size, page).astype(np.int32),
            max_new_tokens=bg_gen,
            priority=0,
        )
        for _ in range(n_bg)
    ]
    items += [
        workloads.WorkItem(
            arrival_step=4 + hi_gap * k,
            prompt=rng.integers(
                1, cfg.vocab_size, int(rng.integers(6, page // 2))
            ).astype(np.int32),
            max_new_tokens=3,
            priority=1,
            deadline_steps=hi_dl,
        )
        for k in range(n_hi)
    ]
    lens = sorted({w.prompt.size for w in items})

    # calibrate seconds-per-step on a bare engine (the monitor changes
    # no compiled program), then arm both modes with the same deadlines
    cal = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(
            max_slots=slots, max_len=max_len, preemption=False
        ),
        params=params,
    )
    _warm_buckets(cal, lens)
    workloads.replay(cal, items, step_s=None)
    _, wall, steps = workloads.replay(cal, items, step_s=None)
    step_s = wall / max(steps, 1)
    # burn windows sized in measured steps: misses must land in both
    # windows the tick they are recorded, and age out ~a bg-gen later
    fast_s = max(8 * step_s, 5e-3)

    def _hi_attainment(fins) -> tuple[int, int]:
        hi = [
            f
            for f in fins
            if f.schedule.priority == 1
            and f.schedule.deadline_s is not None
            and f.reject_reason != REJECT_SHED
        ]
        return sum(1 for f in hi if f.slo_met), len(hi)

    out: dict = {}
    for mode, shed in (("off", False), ("on", True)):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=slots,
                max_len=max_len,
                preemption=False,
                monitor=True,
                slo=SloConfig(
                    target=0.9,
                    fast_window_s=fast_s,
                    slow_window_s=3 * fast_s,
                    warn_burn=2.0,
                    critical_burn=6.0,
                    shed=shed,
                    shed_max_per_tick=2 * slots,
                ),
            ),
            params=params,
        )
        _warm_buckets(eng, lens)
        workloads.replay(eng, items, step_s=None)  # warm, unarmed
        eng.reset_stats()
        fins, wall, steps = workloads.replay(eng, items, step_s=step_s)
        stats = eng.stats_summary()
        sheds = [f for f in fins if f.reject_reason == REJECT_SHED]
        met, n_dl = _hi_attainment(fins)
        out[mode] = {
            "requests": len(fins),
            "hi_with_deadline": n_dl,
            "hi_slo_met": met,
            "hi_attainment": round(met / n_dl, 4) if n_dl else 1.0,
            "sheds": len(sheds),
            "rejected_total": stats["rejected"]["total"],
            "slo_transitions": dict(eng._slo_mon.transitions),
            "wall_s": round(wall, 4),
            "steps": steps,
        }
        if shed:
            assert sheds, "overload under CRITICAL never shed"
            assert all(
                f.finish_reason == "rejected"
                and f.reject_reason == REJECT_SHED
                for f in sheds
            ), "sheds must surface as structured rejections"
            assert out["on"]["slo_transitions"].get("CRITICAL", 0) >= 1
        else:
            assert not sheds and stats["rejected"]["total"] == 0, (
                "shedding disabled must never reject"
            )
    out["hi_attainment_gain"] = round(
        out["on"]["hi_attainment"] - out["off"]["hi_attainment"], 4
    )
    assert out["on"]["hi_attainment"] > out["off"]["hi_attainment"], (
        f"shedding did not raise high-priority attainment: "
        f"on={out['on']['hi_attainment']} "
        f"off={out['off']['hi_attainment']}"
    )
    out["step_s"] = round(step_s, 6)
    return out


def _measure_mesh(smoke: bool) -> dict:
    """Run ``benchmarks.serve_mesh`` in a subprocess and parse its JSON.

    Device count is fixed at the first backend initialization, so the
    simulated 8-device CPU platform must be forced *before* jax imports
    — impossible in this process, which already initialized the default
    platform. The child re-checks the env, so forcing it here keeps the
    bench deterministic no matter which platform the parent grabbed."""
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count=8".strip()
        )
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    cmd = [sys.executable, "-m", "benchmarks.serve_mesh"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(
        cmd, cwd=root, env=env, capture_output=True, text=True
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"serve_mesh failed (rc={proc.returncode}):\n"
            + proc.stderr[-2000:]
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _measure_goodput(cfg, mesh, params, batch: int, smoke: bool) -> dict:
    """The three scheduling scenarios over seeded workload traces."""
    page = cfg.attn_block
    slots = batch

    # ---- burst: deadline'd high-priority burst on a full pool. The
    # step counts give >2x margin on both sides of the deadline: with
    # preemption the burst's e2e is ~burst_gen + a few steps; without,
    # it queues behind ~background_gen steps.
    bg_gen, burst_at, dl = (40, 8, 22) if smoke else (96, 12, 48)
    burst = workloads.poisson_burst(
        np.random.default_rng(11),
        vocab=cfg.vocab_size,
        page=page,
        n_background=slots,
        n_burst=slots,
        burst_step=burst_at,
        background_gen=bg_gen,
        burst_gen=6,
        deadline_steps=dl,
    )
    rows = {
        "burst": _goodput_pair(
            cfg, mesh, params, slots, 3 * page, burst, strict=True
        )
    }

    # ---- long tail: open-loop Poisson arrivals, interactive tier
    # (priority 1, deadline'd shorts) over a heavy batch tail
    tail = workloads.long_tail(
        np.random.default_rng(12),
        vocab=cfg.vocab_size,
        page=page,
        n=12 if smoke else 32,
        mean_gap_steps=3.0 if smoke else 2.0,
        short_gen=(3, 8),
        heavy_gen=bg_gen,
        deadline_steps=30 if smoke else 40,
    )
    rows["long_tail"] = _goodput_pair(
        cfg, mesh, params, slots, 3 * page, tail
    )

    # ---- chat: multi-turn conversations, prefix cache on — turn 2+
    # prompts extend turn 1's history, so admission hits the
    # decode-written pages the engine indexed when turn 1 finished
    n_turns = 2 if smoke else 3
    mk_convs = lambda seed: workloads.chat_turns(
        np.random.default_rng(seed),
        vocab=cfg.vocab_size,
        n_users=slots,
        n_turns=n_turns,
        user_tokens=page,
        # gen page+1: the answer fills the prompt's last page exactly
        # (written = prompt + gen[:-1]), so whole turns become matchable
        gen=page + 1,
    )
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(
            max_slots=slots,
            max_len=(2 * n_turns + 1) * page,
            prefix_cache=True,
        ),
        params=params,
    )
    # warm with same-shaped different-token conversations: compiles the
    # partial-prefill buckets the measured turns hit, without seeding
    # the tree with the measured tokens
    workloads.replay_chat(eng, mk_convs(998))
    eng.reset_stats()
    by_turn, wall, _ = workloads.replay_chat(eng, mk_convs(13))
    later = [f for t, fs in by_turn.items() if t >= 1 for f in fs]
    hit = sum(f.prefix_hit_tokens for f in later)
    plen = sum(int(f.prompt.size) for f in later)
    ttft = [f.ttft_s for fs in by_turn.values() for f in fs]
    stats = eng.stats_summary()
    rows["chat"] = {
        "turns": n_turns,
        "users": slots,
        "wall_s": round(wall, 4),
        "turn2plus_hit_rate": round(hit / plen, 4) if plen else 0.0,
        "turn2plus_hit_tokens": hit,
        "decode_indexed_pages": stats["prefix_cache"][
            "decode_indexed_pages"
        ],
        "ttft_p50_ms": workloads._pct(ttft, 50),
        "ttft_p95_ms": workloads._pct(ttft, 95),
    }
    assert rows["chat"]["turn2plus_hit_rate"] > 0.25, (
        "chat turns no longer hit decode-indexed pages: "
        f"{rows['chat']}"
    )
    return rows


def run(
    smoke: bool = False,
    guards: bool = False,
    trace_out: str | None = None,
    listen: str | None = None,
) -> None:
    cfg = registry.get_smoke(ARCH, sparse=True)
    batch, prompt_len, gen, repeats = BATCH, PROMPT_LEN, GEN, 3
    if smoke:
        # seconds-scale dry run of every scenario: tiny model, tiny
        # traces, one repeat, JSON into a temp dir (the real
        # BENCH_serve.json trajectory stays untouched)
        import tempfile

        from benchmarks import common

        common.set_json_dir(tempfile.mkdtemp(prefix="bench_serve_smoke_"))
        cfg = cfg.replace(num_layers=2, vocab_size=256)
        batch, prompt_len, gen, repeats = 2, 8, 4, 1
    mesh = make_local_mesh()
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(batch, prompt_len), dtype=np.int32
    )

    # ---- seed Server baseline (fixed batch, per-token prefill loop)
    server = Server(cfg, mesh)
    server.generate(prompts[:, :prompt_len], 2)  # warm the decode jit
    t0 = time.perf_counter()
    out = server.generate(prompts, gen)
    server_s = time.perf_counter() - t0
    server_tokens = int(out.size)
    server_tok_s = server_tokens / server_s

    # ---- engine, uniform workload (same requests, shared weights)
    max_len = prompt_len + gen + 1
    engine = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=batch, max_len=max_len),
        params=server.params,
    )
    uniform = _measure_uniform(engine, prompts, gen, repeats=repeats)

    # ---- decode-by-sampler: identical workload, fully-loaded sampling
    # on the same (already warm) engine — sampling is fused into the
    # jit'd step, so this should cost within ~10% of greedy decode
    keys = ("decode_tok_s", "p95_token_latency_ms", "p50_token_latency_ms")
    sampled = _measure_uniform(
        engine, prompts, gen, sampling=SAMPLED, repeats=repeats
    )
    by_sampler = {
        "greedy": {k: uniform[k] for k in keys},
        SAMPLED.kind: {k: sampled[k] for k in keys},
        "sampled_vs_greedy": round(
            sampled["decode_tok_s"] / max(uniform["decode_tok_s"], 1e-9),
            4,
        ),
    }

    # ---- per-impl decode comparison: jnp gather path vs the Pallas
    # paged kernel (off TPU the interpreted kernel stands in for it, so
    # the json tracks parity-path numbers on every platform)
    base_impl = engine.paged_impl
    other_impl = "interpret" if base_impl == "gather" else "gather"
    by_impl = {base_impl: {k: uniform[k] for k in keys}}
    engine_o = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=batch, max_len=max_len),
        params=server.params,
        paged_impl=other_impl,
    )
    other = _measure_uniform(engine_o, prompts, gen, repeats=repeats)
    by_impl[other_impl] = {k: other[k] for k in keys}

    # ---- dispatch-guard scenario: the steady-state decode loop runs
    # inside repro.analysis.guards.DispatchGuard. Counters are always
    # recorded in the payload; under --guards (and in the --smoke tier-1
    # gate) the guard *raises* on any recompile or implicit D2H sync, so
    # a hot-path regression fails the run instead of just drifting a
    # number.
    dispatch_guard = _measure_guarded(
        engine, prompts, gen, enforce=guards or smoke
    )

    # ---- engine, mixed-length trace with mid-flight arrivals
    engine2 = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=batch, max_len=2 * max_len),
        params=server.params,
    )
    rng = np.random.default_rng(1)
    n_req = 2 * batch
    lens = [int(rng.integers(8, 2 * prompt_len)) for _ in range(n_req)]
    gens = [int(rng.integers(max(gen // 2, 1), 2 * gen))
            for _ in range(n_req)]
    # warm every (N, S) bucket the trace can hit, not just prompt-32:
    # otherwise other buckets JIT inside the measured region and pollute
    # the latency percentiles
    _warm_buckets(engine2, lens)
    t0 = time.perf_counter()
    for i in range(n_req // 2):
        engine2.submit(
            rng.integers(0, cfg.vocab_size, lens[i]).astype(np.int32),
            gens[i],
        )
    fins = []
    for _ in range(max(gen // 2, 1)):  # let the first wave progress
        fins += engine2.step()
    for i in range(n_req // 2, n_req):  # late arrivals, admitted mid-flight
        engine2.submit(
            rng.integers(0, cfg.vocab_size, lens[i]).astype(np.int32),
            gens[i],
        )
    fins += engine2.drain()
    mixed_s = time.perf_counter() - t0
    mixed = engine2.stats_summary()
    mixed["wall_tok_s"] = round(
        sum(len(f.tokens) for f in fins) / mixed_s, 2
    )
    mixed["requests"] = n_req

    # ---- prefill-heavy: many short ragged prompts, tiny gens — admission
    # dominates. Batched bucketed admission vs per-request baseline on the
    # identical trace (shared weights, same slots/capacity).
    rng = np.random.default_rng(2)
    ph_n = (2 if smoke else 8) * batch
    ph_prompts = [
        rng.integers(
            0, cfg.vocab_size, int(rng.integers(4, 3 * prompt_len))
        ).astype(np.int32)
        for _ in range(ph_n)
    ]
    ph_gens = [int(rng.integers(2, 5)) for _ in range(ph_n)]
    ph_lens = [p.size for p in ph_prompts]
    # Both engines are built and warmed before any timing, then every
    # repeat measures the two modes back-to-back and the committed
    # speedup is the median of the per-repeat *paired* ratios: a patch
    # of machine load lands on both legs of a pair instead of skewing
    # whichever mode's sequential block it happened to hit (the old
    # per-mode best-of blocks drifted run-to-run for exactly that
    # reason).
    ph_engines = {}
    for mode, batch_cap in (("batched", 0), ("per_request", 1)):
        eng = Engine(
            cfg,
            mesh,
            # 2x slots: admission waves are what this scenario measures
            engine_cfg=EngineConfig(
                max_slots=2 * batch,
                max_len=2 * max_len,
                max_prefill_batch=batch_cap,
            ),
            params=server.params,
        )
        _warm_buckets(eng, ph_lens)
        ph_engines[mode] = eng
    ph_pairs = [
        {
            m: _measure_trace(ph_engines[m], ph_prompts, ph_gens, repeats=1)
            for m in ("batched", "per_request")
        }
        for _ in range(repeats)
    ]
    ph_ratios = [
        p["batched"]["wall_tok_s"]
        / max(p["per_request"]["wall_tok_s"], 1e-9)
        for p in ph_pairs
    ]
    ph = ph_pairs[int(np.argsort(ph_ratios)[len(ph_ratios) // 2])]
    ph_speedup = round(sorted(ph_ratios)[len(ph_ratios) // 2], 2)

    # ---- prefix cache: shared-system-prompt trace, cache on vs off
    prefix = _measure_prefix_cache(
        cfg, mesh, server.params, batch, smoke, repeats
    )

    # ---- observability: tracer on vs off on the uniform workload —
    # proves the span tracer stays inside its overhead budget and (via
    # --trace-out) round-trips a validatable Perfetto timeline
    obs = _measure_observability(
        cfg, mesh, server.params, batch, prompt_len, gen, repeats,
        trace_out=trace_out,
    )

    # ---- observability_live: the full telemetry plane (windows + SLO
    # monitor + memory gauges) vs a bare engine, plus the burn-rate
    # load-shed scenario; --listen adds a mid-run /metrics scrape
    obs_live = _measure_observability_live(
        cfg, mesh, server.params, batch, prompt_len, gen, repeats,
        smoke, listen=listen,
    )
    obs_live["slo_shed"] = _measure_slo_shed(cfg, mesh, server.params, batch)

    # ---- goodput: SLO-aware scheduling scenarios (burst / long-tail /
    # multi-turn chat) over seeded workload traces
    good = _measure_goodput(cfg, mesh, server.params, batch, smoke)

    # ---- mesh: TP decode scaling + DP replica routing on a simulated
    # 8-device host mesh (subprocess — see _measure_mesh)
    meshrow = _measure_mesh(smoke)

    payload = {
        "config": {
            "arch": ARCH,
            "smoke": True,
            "sparse": True,
            "batch": batch,
            "prompt_len": prompt_len,
            "gen": gen,
            "page": cfg.attn_block,
            "slots": batch,
        },
        "server": {
            "tok_s": round(server_tok_s, 2),
            "total_tokens": server_tokens,
            "wall_s": round(server_s, 4),
        },
        "engine_uniform": uniform,
        "engine_mixed": mixed,
        "engine_prefill_heavy": ph["batched"],
        "prefill_heavy_baseline": ph["per_request"],
        "prefill_heavy_speedup": ph_speedup,
        "decode_by_impl": by_impl,
        "decode_by_sampler": by_sampler,
        "dispatch_guard": dispatch_guard,
        "observability": obs,
        "observability_live": obs_live,
        "prefix_cache": prefix,
        "goodput": good,
        "mesh": meshrow,
        "paged_impl_default": base_impl,
        "speedup_vs_server": round(uniform["tok_s"] / server_tok_s, 2),
    }
    emit_json("BENCH_serve.json", payload)
    emit(
        "serve_engine/uniform",
        1e6 / max(uniform["wall_tok_s"], 1e-9),
        f"tok_s={uniform['tok_s']};server_tok_s={server_tok_s:.2f}"
        f";speedup={payload['speedup_vs_server']}x",
    )
    emit(
        "serve_engine/mixed",
        1e6 * mixed_s / max(mixed["generated_tokens"], 1),
        f"tok_s={mixed['tok_s']};occupancy={mixed['mean_occupancy']}"
        f";p95_ms={mixed['p95_token_latency_ms']}",
    )
    emit(
        "serve_engine/prefill_heavy",
        1e6 / max(ph["batched"]["wall_tok_s"], 1e-9),
        f"wall_tok_s={ph['batched']['wall_tok_s']}"
        f";baseline={ph['per_request']['wall_tok_s']}"
        f";speedup={payload['prefill_heavy_speedup']}x"
        f";req_per_prefill={ph['batched']['mean_prefill_batch']}",
    )
    for impl, row in by_impl.items():
        emit(
            f"serve_engine/decode_{impl}",
            1e6 / max(row["decode_tok_s"], 1e-9),
            f"decode_tok_s={row['decode_tok_s']}"
            f";p95_ms={row['p95_token_latency_ms']}",
        )
    emit(
        "serve_engine/decode_sampled",
        1e6 / max(sampled["decode_tok_s"], 1e-9),
        f"decode_tok_s={sampled['decode_tok_s']}"
        f";greedy_tok_s={uniform['decode_tok_s']}"
        f";sampled_vs_greedy={by_sampler['sampled_vs_greedy']}x",
    )
    emit(
        "serve_engine/dispatch_guard",
        1e6 * dispatch_guard["steps"],
        f"steps={dispatch_guard['steps']}"
        f";compiles={dispatch_guard['compiles']}"
        f";implicit_d2h={dispatch_guard['implicit_d2h']}"
        f";explicit_syncs={dispatch_guard['explicit_syncs']}"
        f";enforced={dispatch_guard['enforced']}",
    )
    emit(
        "serve_engine/observability",
        1e6 / max(obs["traced"]["decode_tok_s"], 1e-9),
        f"traced_tok_s={obs['traced']['decode_tok_s']}"
        f";off_tok_s={obs['off']['decode_tok_s']}"
        f";traced_vs_off={obs['traced_vs_off']}x"
        f";overhead_pct={obs['overhead_pct']}"
        f";events={obs['events_recorded']}",
    )
    emit(
        "serve_engine/observability_live",
        1e6 / max(obs_live["monitored"]["decode_tok_s"], 1e-9),
        f"monitored_tok_s={obs_live['monitored']['decode_tok_s']}"
        f";off_tok_s={obs_live['off']['decode_tok_s']}"
        f";monitored_vs_off={obs_live['monitored_vs_off']}x"
        f";overhead_pct={obs_live['overhead_pct']}",
    )
    shed = obs_live["slo_shed"]
    emit(
        "serve_engine/slo_shed",
        1e6 * (1.0 - shed["on"]["hi_attainment"] + 1e-9),
        f"hi_attainment_on={shed['on']['hi_attainment']}"
        f";hi_attainment_off={shed['off']['hi_attainment']}"
        f";gain={shed['hi_attainment_gain']}"
        f";sheds={shed['on']['sheds']}"
        f";critical_transitions={shed['on']['slo_transitions'].get('CRITICAL', 0)}",
    )
    emit(
        "serve_engine/prefix_cache",
        1e6 * prefix["on"]["prefill_s"],
        f"admission_speedup={prefix['admission_speedup']}x"
        f";hit_rate={prefix['on']['hit_rate']}"
        f";wall_speedup={prefix['wall_speedup']}x",
    )
    for name in ("burst", "long_tail"):
        row = good[name]
        emit(
            f"serve_engine/goodput_{name}",
            1e6 * row["on"]["ttft_p95_ms"],
            f"slo_on={row['on']['slo_attainment']}"
            f";slo_off={row['off']['slo_attainment']}"
            f";preemptions={row['on']['preemptions']}"
            f";swap_out_bytes={row['on']['swap_out_bytes']}",
        )
    emit(
        "serve_engine/goodput_chat",
        1e6 * good["chat"]["ttft_p95_ms"],
        f"turn2plus_hit_rate={good['chat']['turn2plus_hit_rate']}"
        f";decode_indexed_pages={good['chat']['decode_indexed_pages']}",
    )
    top_tp = str(max(int(k) for k in meshrow["by_tp"]))
    emit(
        "serve_engine/mesh",
        1e6 / max(meshrow["by_tp"][top_tp]["decode_tok_s"], 1e-9),
        f"tp{top_tp}_decode_tok_s={meshrow['by_tp'][top_tp]['decode_tok_s']}"
        f";tp1={meshrow['by_tp']['1']['decode_tok_s']}"
        f";streams_equal={meshrow['streams_equal']}"
        f";router_tok_s={meshrow['router']['wall_tok_s']}",
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale dry run (tier-1 gate)")
    ap.add_argument("--guards", action="store_true",
                    help="enforce the dispatch guard: raise on any "
                         "recompile or implicit device->host sync in "
                         "the steady-state decode loop (implied by "
                         "--smoke)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the observability scenario's traced "
                         "engine ring as Perfetto JSON (tier-1 "
                         "round-trips and validates it)")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve live telemetry from the monitored "
                         "engine during the observability_live "
                         "scenario and scrape /metrics mid-run "
                         "(port 0 = ephemeral)")
    _args = ap.parse_args()
    run(smoke=_args.smoke, guards=_args.guards, trace_out=_args.trace_out,
        listen=_args.listen)
