"""Paper Fig 5 / Table 4: dense vs Pixelfly MLP-Mixer / ViT training step.

CPU-scale twin of the ImageNet table: same architecture family, reduced
width/depth. Reports wall-clock per train step, parameter ratio, and FLOP
ratio (the transferable part of the 1.7-2.3x claim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.models import vision as V


def _train_step(cfg, apply_fn):
    def loss_fn(params, x, y):
        lg = apply_fn(cfg, params, x)
        return -jnp.take_along_axis(
            jax.nn.log_softmax(lg), y[:, None], axis=1
        ).mean()

    @jax.jit
    def step(params, x, y):
        l, g = jax.value_and_grad(loss_fn)(params, x, y)
        return jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g), l

    return step


def run() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 64, 192)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 100, 32), jnp.int32)

    for kind, init_fn, apply_fn in [
        ("mixer", V.init_mixer, V.apply_mixer),
        ("vit", V.init_vit, V.apply_vit),
    ]:
        times, params_n = {}, {}
        for sparse in (False, True):
            cfg = V.VisionConfig(
                kind=kind, num_layers=4, d_model=256, num_heads=4,
                d_ff=1024, num_patches=64, num_classes=100, patch_dim=192,
                token_ff=128, sparse=sparse, sparse_density=0.15,
                sparse_block=32,
            )
            params = init_fn(jax.random.PRNGKey(0), cfg)
            step = _train_step(cfg, apply_fn)
            times[sparse] = time_fn(step, params, x, y, warmup=1, iters=3)
            params_n[sparse] = sum(p.size for p in jax.tree.leaves(params))
        emit(
            f"vision_speedup/{kind}",
            times[True],
            f"dense_us={times[False]:.0f};speedup={times[False]/times[True]:.2f}x"
            f";param_ratio={params_n[True]/params_n[False]:.3f}",
        )


if __name__ == "__main__":
    run()
