"""Paper App. J / Fig 11: flat butterfly (one block-sparse GEMM) vs the
sequential product of butterfly factor matrices.

The paper measures up to 3x on a V100; the structural cause — log2(k)
dependent GEMMs vs one — is hardware-independent and reproduces on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import butterfly as bf
from repro.kernels import ref


def run(n: int = 1024, block: int = 32, batch: int = 512) -> None:
    rng = np.random.default_rng(0)
    nb = n // block
    x = jnp.asarray(rng.standard_normal((batch, n)), jnp.float32)

    for max_stride in [4, 16, nb]:
        strides = bf.flat_butterfly_strides(max_stride)
        # --- product form: x @ (I + lam B_2) @ (I + lam B_4) ...
        factors = [
            jnp.asarray(
                np.eye(n) + 0.1 * bf.butterfly_factor_matrix(
                    nb, 2 * s // 1 if s > 1 else 2, rng, block=block
                ),
                jnp.float32,
            )
            for s in ([1] + strides)
        ]

        @jax.jit
        def product(x, factors=tuple(factors)):
            y = x
            for f in factors:
                y = y @ f
            return y

        # --- flat form: one BSR sparse matmul with the same nnz structure
        pat = bf.make_pattern(n, n, block=block, max_stride=max_stride)
        blocks = jnp.asarray(
            rng.standard_normal((pat.nb_out, pat.r, block, block))
            / np.sqrt(pat.r * block),
            jnp.float32,
        )
        cols = jnp.asarray(pat.cols)

        @jax.jit
        def flat(x):
            return ref.bsr_matmul_gather(x, blocks, cols)

        t_prod = time_fn(product, x)
        t_flat = time_fn(flat, x)
        emit(
            f"flat_vs_product/k={max_stride}",
            t_flat,
            f"product_us={t_prod:.1f};speedup={t_prod / t_flat:.2f}x",
        )


if __name__ == "__main__":
    run()
