"""Shared benchmark utilities: timing + CSV/JSON emission."""

from __future__ import annotations

import json
import os
import time

import jax

# Set by ``benchmarks.run --json DIR``; suites drop their JSON artifacts
# (e.g. BENCH_serve.json) here. Defaults to the working directory.
JSON_DIR: str = "."


def set_json_dir(path: str) -> None:
    global JSON_DIR
    JSON_DIR = path
    os.makedirs(path, exist_ok=True)


def emit_json(filename: str, payload: dict) -> str:
    """Write a benchmark artifact under JSON_DIR; returns its path."""
    path = os.path.join(JSON_DIR, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    return path


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
