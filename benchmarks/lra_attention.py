"""Paper Fig 9 (LRA): dense vs pixelfly block-sparse attention at long
sequence lengths (1k-4k, the LRA range). Measures the attention op itself
(the bottleneck the 5.2x speedup comes from) and the key-read fraction."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import attn_pattern as ap
from repro.models.layers import flash_attention_jnp, sparse_attention_jnp


def run() -> None:
    rng = np.random.default_rng(0)
    b, hk, g, d = 2, 4, 1, 64
    for s in [1024, 2048, 4096]:
        q = jnp.asarray(rng.standard_normal((b, s, hk, g, d)) * 0.1, jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, hk, d)) * 0.1, jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, hk, d)) * 0.1, jnp.float32)
        cfg = ap.AttentionPatternConfig(
            block=128, local_blocks=1, max_stride=0, global_blocks=1
        )
        mask = ap.pixelfly_attention_block_mask(s, s, cfg, causal=True)
        sched = ap.block_schedule(mask, 128, 128)

        dense = jax.jit(
            functools.partial(
                flash_attention_jnp, causal=True, chunk=512, sm_scale=d**-0.5
            )
        )
        sparse = jax.jit(
            lambda q, k, v: sparse_attention_jnp(
                q, k, v, sched, causal=True, sm_scale=d**-0.5
            )
        )
        t_d = time_fn(dense, q, k, v, warmup=1, iters=3)
        t_s = time_fn(sparse, q, k, v, warmup=1, iters=3)
        keys = ap.keys_per_query(mask, 128, s)
        emit(
            f"lra_attention/s={s}",
            t_s,
            f"dense_us={t_d:.0f};speedup={t_d/t_s:.2f}x"
            f";keys_per_query={keys:.0f}/{s}",
        )


if __name__ == "__main__":
    run()
