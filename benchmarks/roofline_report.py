"""§Roofline report: formats the dry-run sweep JSON into the per-(arch x
shape x mesh) roofline table (terms, bottleneck, MODEL_FLOPS ratio).

Reads dryrun_baseline.json produced by:
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes \
      --json dryrun_baseline.json
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "dryrun_baseline.json")


def run(path: str = DEFAULT) -> None:
    if not os.path.exists(path):
        emit("roofline/missing", 0.0, f"run dryrun --all first ({path})")
        return
    rows = json.load(open(path))
    for r in rows:
        if not r.get("ok"):
            emit(
                f"roofline/{r['arch']}/{r['shape']}/{r.get('multi_pod')}",
                0.0,
                f"FAILED:{r.get('error', '?')[:60]}",
            )
            continue
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r["compute_s"] * 1e6,
            f"mem_ms={r['memory_s']*1e3:.1f};coll_ms={r['collective_s']*1e3:.1f}"
            f";bottleneck={r['bottleneck']}"
            f";useful={r['useful_flops_ratio']:.3f}"
            f";temp_gb={(r['bytes_per_device'] or 0)/1e9:.1f}",
        )


if __name__ == "__main__":
    run()
