#!/usr/bin/env bash
# Tier-1 gate: the fast test subset (everything not marked `slow`),
# including the interpret-mode paged-kernel parity suite
# (tests/test_kernels_paged.py) so the Pallas/jnp differential gates
# every PR. The full 5-minute suite is `PYTHONPATH=src python -m pytest -q`.
#
#   scripts/tier1.sh            # fast subset
#   scripts/tier1.sh -x         # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# Preflight: collection must be clean. Marker-less --co imports and
# collects EVERY test file — including slow-only ones — so a syntax or
# import error anywhere fails the gate here instead of going unnoticed
# until someone runs the full suite. (Marker filtering happens after
# collection, so one pass covers both the fast and the slow set.)
# (exit 5 = "no tests collected" — clean collection, let pytest report it)
rc=0
python -m pytest -q --co "$@" > /dev/null 2>&1 || rc=$?
if [ "$rc" -ne 0 ] && [ "$rc" -ne 5 ]; then
    echo "tier1: test collection failed" >&2
    python -m pytest -q --co "$@" || exit 1
fi
# Static analysis first: jaxlint is the cheapest leg (AST-only, no jax
# import) and a hot-path violation should fail the gate before any
# benchmark or test burns a minute. The committed baseline holds the
# accepted findings — anything fresh, or a stale baseline entry, fails.
echo "tier1: jaxlint src/"
python -m repro.analysis.jaxlint src --baseline jaxlint_baseline.txt
# Benchmark-script gate: the serving benchmark's seconds-scale dry run
# (tiny model, every scenario, JSON to a temp dir). Catches API drift in
# benchmarks/ that no unit test imports — breakage fails tier 1 here
# instead of rotting until the next full benchmark run. --smoke implies
# --guards: the dispatch-guard scenario runs *enforced*, so a recompile
# or implicit device->host sync in steady-state decode fails the gate.
# --trace-out round-trips the observability scenario's span ring
# through the Perfetto exporter; the validator then proves the file is
# openable (monotonic timestamps per track, matched B/E pairs, nonempty
# slot tracks, monotonic counter series) so a tracer regression can't
# ship an unreadable timeline. --listen serves live telemetry from the
# monitored engine on an ephemeral port and scrapes /metrics +
# /healthz *mid-decode* (round-tripping obs/prom.parse), so an
# exposition or windowed-aggregation regression fails the gate here.
echo "tier1: benchmarks/serve_engine.py --smoke"
trace_out="$(mktemp -t tier1_trace_XXXXXX.json)"
trap 'rm -f "$trace_out"' EXIT
python -m benchmarks.serve_engine --smoke --trace-out "$trace_out" \
    --listen 127.0.0.1:0 > /dev/null
echo "tier1: perfetto trace round-trip"
python - "$trace_out" <<'EOF'
import sys
from repro.obs.perfetto import validate_trace_file
print("trace ok:", validate_trace_file(sys.argv[1]))
EOF
# Trajectory report (non-fatal): how the tracked BENCH_serve.json
# numbers moved vs the committed baseline. Pure reporting — benchmark
# noise must not gate tier 1; scripts/bench_diff.py --strict exists for
# CI jobs that do want a hard gate.
python scripts/bench_diff.py || true
# Simulated-mesh leg: sharded-engine stream parity and the sharded-pool
# fuzz trace need >1 device, and device count is fixed at the first
# backend init — so they run in their own process on 8 forced host CPU
# devices. (The main pytest pass below collects the same files but
# skips the mesh-gated tests on its single default device.)
echo "tier1: simulated 8-device mesh leg"
JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q -m "not slow" \
    tests/test_mesh_serving.py tests/test_paged_cache_props.py
exec python -m pytest -q -m "not slow" --durations=10 "$@"
