#!/usr/bin/env bash
# Tier-1 gate: the fast test subset (everything not marked `slow`).
# The full 5-minute suite is `PYTHONPATH=src python -m pytest -q`.
#
#   scripts/tier1.sh            # fast subset
#   scripts/tier1.sh -x         # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -q \
    -m "not slow" --continue-on-collection-errors "$@"
