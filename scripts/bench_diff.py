#!/usr/bin/env python
"""Per-scenario regression report for BENCH_serve.json trajectories.

Compares the working-tree ``BENCH_serve.json`` against a baseline —
by default the committed copy (``git show HEAD:BENCH_serve.json``) —
and prints one table row per tracked metric with the relative change.
Rows whose metric moved against its preferred direction by more than
``--threshold`` (default 10%) are flagged.

``scripts/tier1.sh`` runs this after the benchmark smoke as a
*non-fatal* report line: trajectory drift shows up in every tier-1 run
without turning benchmark noise into a gate. Exit code is 0 unless
``--strict`` is given (then flagged regressions exit 1).

  python scripts/bench_diff.py                       # vs HEAD
  python scripts/bench_diff.py --baseline-ref HEAD~1 # vs an older PR
  python scripts/bench_diff.py --baseline other.json # vs a file
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys

# (label, path into the payload, higher-is-better)
METRICS = [
    ("server tok/s", ("server", "tok_s"), True),
    ("uniform decode tok/s", ("engine_uniform", "decode_tok_s"), True),
    ("uniform p95 ms", ("engine_uniform", "p95_token_latency_ms"), False),
    ("mixed wall tok/s", ("engine_mixed", "wall_tok_s"), True),
    ("prefill-heavy speedup", ("prefill_heavy_speedup",), True),
    (
        "decode[gather] tok/s",
        ("decode_by_impl", "gather", "decode_tok_s"),
        True,
    ),
    (
        "decode[interpret] tok/s",
        ("decode_by_impl", "interpret", "decode_tok_s"),
        True,
    ),
    (
        "decode[pallas] tok/s",
        ("decode_by_impl", "pallas", "decode_tok_s"),
        True,
    ),
    ("sampled/greedy decode", ("decode_by_sampler", "sampled_vs_greedy"), True),
    ("prefix admission speedup", ("prefix_cache", "admission_speedup"), True),
    ("prefix hit rate", ("prefix_cache", "on", "hit_rate"), True),
    (
        "goodput[burst] SLO attainment",
        ("goodput", "burst", "on", "slo_attainment"),
        True,
    ),
    (
        "goodput[burst] attainment gain",
        ("goodput", "burst", "attainment_gain"),
        True,
    ),
    (
        "goodput[long_tail] SLO attainment",
        ("goodput", "long_tail", "on", "slo_attainment"),
        True,
    ),
    (
        "goodput[chat] turn-2+ hit rate",
        ("goodput", "chat", "turn2plus_hit_rate"),
        True,
    ),
    ("guard compiles/step", ("dispatch_guard", "compiles"), False),
    ("guard implicit D2H", ("dispatch_guard", "implicit_d2h"), False),
    (
        "observability traced/off decode",
        ("observability", "traced_vs_off"),
        True,
    ),
    (
        "observability traced decode tok/s",
        ("observability", "traced", "decode_tok_s"),
        True,
    ),
    (
        "live plane monitored/off decode",
        ("observability_live", "monitored_vs_off"),
        True,
    ),
    (
        "live plane monitored decode tok/s",
        ("observability_live", "monitored", "decode_tok_s"),
        True,
    ),
    (
        "slo-shed hi-pri attainment (on)",
        ("observability_live", "slo_shed", "on", "hi_attainment"),
        True,
    ),
    (
        "slo-shed attainment gain",
        ("observability_live", "slo_shed", "hi_attainment_gain"),
        True,
    ),
    ("mesh tp=1 decode tok/s", ("mesh", "by_tp", "1", "decode_tok_s"), True),
    ("mesh tp=8 decode tok/s", ("mesh", "by_tp", "8", "decode_tok_s"), True),
    ("mesh streams equal", ("mesh", "streams_equal"), True),
    ("mesh router wall tok/s", ("mesh", "router", "wall_tok_s"), True),
]


def _dig(payload: dict, path: tuple) -> float | None:
    node = payload
    for key in path:
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node if isinstance(node, (int, float)) else None


def _load_baseline(args) -> dict | None:
    if args.baseline:
        try:
            with open(args.baseline) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_diff: cannot read baseline: {e}", file=sys.stderr)
            return None
    try:
        out = subprocess.run(
            ["git", "show", f"{args.baseline_ref}:BENCH_serve.json"],
            capture_output=True,
            text=True,
            check=True,
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, OSError, json.JSONDecodeError):
        print(
            f"bench_diff: no committed BENCH_serve.json at "
            f"{args.baseline_ref} (first run?)",
            file=sys.stderr,
        )
        return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_serve.json")
    ap.add_argument(
        "--baseline", default=None, help="baseline json file (overrides git)"
    )
    ap.add_argument(
        "--baseline-ref", default="HEAD", help="git ref for the baseline"
    )
    ap.add_argument("--threshold", type=float, default=0.10)
    ap.add_argument(
        "--strict", action="store_true", help="exit 1 on flagged regressions"
    )
    args = ap.parse_args()

    try:
        with open(args.current) as f:
            cur = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        # Missing or unparsable benchmark output: informational in the
        # default (tier-1, non-fatal) mode, but a hard failure under
        # --strict — a CI job gating on benchmark drift must not pass
        # green because the numbers it gates on don't exist.
        print(f"bench_diff: cannot read {args.current}: {e}", file=sys.stderr)
        return 1 if args.strict else 0
    base = _load_baseline(args)
    if base is None:
        # a missing committed baseline is a legitimate first run, but an
        # explicitly-passed baseline file that cannot be read gates
        # under --strict like the current file does
        return 1 if (args.strict and args.baseline) else 0

    rows, flagged = [], 0
    for label, path, higher in METRICS:
        b, c = _dig(base, path), _dig(cur, path)
        if b is None and c is None:
            continue
        if b is None or c is None:
            # a tracked trajectory vanishing IS a regression — flag it
            # so --strict gates it; a metric new in this PR is fine
            if c is None:
                flagged += 1
            rows.append((label, b, c, "", "new" if b is None else "GONE"))
            continue
        rel = (c - b) / abs(b) if b else 0.0
        worse = -rel if higher else rel
        flag = "REGRESSION" if worse > args.threshold else ""
        flagged += bool(flag)
        rows.append((label, b, c, f"{rel:+.1%}", flag))

    # Top-level trajectory scan: a scenario block added by the current
    # PR is reported as "new" and never flagged (growing the benchmark
    # must not strict-fail the very run that grows it); a block that
    # *vanished* is a regression — some scenario stopped being measured
    # — and gates under --strict like any other flagged row.
    for key in sorted(set(base) | set(cur)):
        if key == "config" or (key in base) == (key in cur):
            continue
        gone = key not in cur
        flagged += gone
        rows.append(
            (f"trajectory[{key}]", None, None, "", "GONE" if gone else "new")
        )

    w = max(len(r[0]) for r in rows) if rows else 0
    fmt = "%s%-*s  %10s  %10s  %8s  %s"

    def num(x):
        return "-" if x is None else f"{x:g}"

    print(f"bench_diff: BENCH_serve.json vs {args.baseline or args.baseline_ref}")
    print(fmt % ("  ", w, "metric", "baseline", "current", "delta", ""))
    for label, b, c, d, flag in rows:
        print(fmt % ("  ", w, label, num(b), num(c), d, flag))
    if flagged:
        print(
            f"bench_diff: {flagged} metric(s) regressed > "
            f"{args.threshold:.0%}"
        )
    return 1 if (flagged and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
