#!/usr/bin/env bash
# CI gate: tier 1 (fast test subset + benchmark smoke + non-fatal drift
# report) followed by a HARD benchmark-drift gate.
#
# tier1.sh keeps `scripts/bench_diff.py` advisory so benchmark noise
# never blocks local iteration; CI wants the opposite — a working-tree
# `BENCH_serve.json` that regressed a tracked trajectory (or dropped
# one entirely) against the committed baseline fails the job. Override
# the baseline with BENCH_BASELINE_REF (e.g. HEAD~1 to gate a PR that
# regenerated BENCH_serve.json against the previous PR's numbers).
#
#   scripts/ci.sh           # tier1, then bench_diff --strict vs HEAD
#   BENCH_BASELINE_REF=HEAD~1 scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

scripts/tier1.sh "$@"

# Strict static-analysis gate: same lint as tier 1 plus the
# only-shrinks check — a PR may remove jaxlint_baseline.txt entries
# (fixing an accepted finding) but never add one without the reviewer
# seeing it fail here first.
echo "ci: jaxlint --check-baseline-growth"
python -m repro.analysis.jaxlint src \
    --baseline jaxlint_baseline.txt --check-baseline-growth

echo "ci: scripts/bench_diff.py --strict"
python scripts/bench_diff.py --strict \
    --baseline-ref "${BENCH_BASELINE_REF:-HEAD}"
