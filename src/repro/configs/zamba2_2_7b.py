"""zamba2-2.7b [hybrid] — Mamba2 blocks + a shared attention block
[arXiv:2411.15242; hf]. 54 layers as 9 cycles of (5 Mamba2 + 1 shared
attn+MLP block); the attention block's parameters are shared across all 9
positions (Zamba's signature trick)."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, rope_theta=10000.0,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=6, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32", ssm_state=32, ssm_head_dim=32,
        attn_every=3, ssm_chunk=32,
    )
