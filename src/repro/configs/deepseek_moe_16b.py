"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066; hf]. First layer is a dense MLP (d_ff 10944)."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400, rope_theta=10000.0,
    moe_num_experts=64, moe_top_k=6, moe_num_shared=2, moe_d_ff=1408,
    moe_first_dense=1, moe_dense_ff=10944,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=256, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32",
        moe_num_experts=8, moe_top_k=2, moe_num_shared=2, moe_d_ff=256,
        moe_first_dense=1, moe_dense_ff=512,
    )
