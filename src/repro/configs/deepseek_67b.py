"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954; hf]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400, rope_theta=10000.0,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32",
    )
