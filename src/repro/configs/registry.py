"""Architecture registry: ``--arch <id>`` resolution for every entry point.

``get(name)`` returns the exact published config; ``get(name, sparse=True)``
returns its Pixelfly-sparsified twin (the paper's technique switched on with
the §3.3 defaults); ``get_smoke(name)`` returns the reduced same-family
config used by the per-arch smoke tests.
"""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec

_MODULES = {
    "deepseek-67b": "repro.configs.deepseek_67b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "smollm-360m": "repro.configs.smollm_360m",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "musicgen-large": "repro.configs.musicgen_large",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
}

ARCH_NAMES = list(_MODULES)

# Mesh-usage strategy per arch (see repro.distributed.sharding.Strategy):
# TP+FSDP for big models; pure FSDP/DP for small ones where TP-16 would be
# dominated by per-layer activation collectives.
DEFAULT_STRATEGY = {
    "deepseek-67b": "tp",
    "qwen3-1.7b": "fsdp",
    "qwen2-1.5b": "fsdp",
    "smollm-360m": "fsdp",
    "qwen2-vl-7b": "tp",
    "deepseek-moe-16b": "tp",  # expert parallelism needs the model axis
    "kimi-k2-1t-a32b": "tp",
    "musicgen-large": "tp",
    "zamba2-2.7b": "tp",
    "mamba2-130m": "fsdp",
}

# Archs whose long_500k cell runs (sub-quadratic sequence mixing).
LONG_CONTEXT_ARCHS = {"mamba2-130m", "zamba2-2.7b"}
# Beyond-paper: pixelfly-sparse attention makes decode sub-quadratic, so
# this full-attention arch also runs long_500k when sparse=True.
LONG_CONTEXT_SPARSE_ARCHS = {"smollm-360m"}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(
            f"unknown arch {name!r}; known: {', '.join(ARCH_NAMES)}"
        )
    return importlib.import_module(_MODULES[name])


def get(
    name: str,
    *,
    sparse: bool = False,
    density: float | None = None,
    **overrides,
) -> ModelConfig:
    cfg: ModelConfig = _module(name).FULL
    if sparse:
        cfg = cfg.replace(
            sparse=True,
            sparse_attention=(cfg.family not in ("ssm",)),
        )
        if density is not None:
            cfg = cfg.replace(sparse_density=density)
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def get_smoke(name: str, *, sparse: bool = False, **overrides) -> ModelConfig:
    cfg: ModelConfig = _module(name).smoke()
    if sparse:
        cfg = cfg.replace(
            sparse=True,
            sparse_density=0.5,
            sparse_attention=(cfg.family not in ("ssm",)),
        )
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def shapes_for(name: str, *, sparse: bool = False) -> list[ShapeSpec]:
    """The assigned shape cells for an arch (long_500k gated per DESIGN §5)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if name in LONG_CONTEXT_ARCHS or (
        sparse and name in LONG_CONTEXT_SPARSE_ARCHS
    ):
        out.append(SHAPES["long_500k"])
    return out
