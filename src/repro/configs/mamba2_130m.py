"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060;
unverified]. Attention-free: the paper's attention sparsity is
inapplicable (DESIGN.md §5); pixelfly applies to out_proj (in_proj's
fused width 3352 is not block-divisible and stays dense)."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0, head_dim=64,
    d_ff=0, vocab_size=50280, tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, vocab_size=512, sparse_block=64,
        dtype="float32", ssm_state=32, ssm_head_dim=32, ssm_chunk=32,
    )
