"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32",
    )
