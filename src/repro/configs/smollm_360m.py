"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM; hf].

d_model=960 is not a multiple of 128, so the pixelfly hardware block is 64
for this arch (8x128 VPU tile still aligned; MXU runs at half tile).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152, tie_embeddings=True, rope_theta=10000.0,
    sparse_block=64,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=192, num_heads=3, num_kv_heads=1, head_dim=64,
        d_ff=512, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32",
    )
