"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. The EnCodec frontend is a stub: input_specs()
provides precomputed frame embeddings; logits are over the 2048-entry
codebook vocab."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, rope_theta=10000.0, embed_inputs=False,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=256, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32",
    )
