"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family; hf]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen3-1.7b", family="dense",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=6144, vocab_size=151936, qk_norm=True, tie_embeddings=True,
    rope_theta=1e6,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32",
    )
