"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 routed experts top-8
+ 1 shared, first layer dense (paper-table config) [arXiv:2501.kimi2;
unverified]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840, rope_theta=50000.0,
    moe_num_experts=384, moe_top_k=8, moe_num_shared=1, moe_d_ff=2048,
    moe_first_dense=1, moe_dense_ff=18432,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=256, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32",
        moe_num_experts=8, moe_top_k=2, moe_num_shared=1, moe_d_ff=256,
        moe_first_dense=1, moe_dense_ff=512,
    )
