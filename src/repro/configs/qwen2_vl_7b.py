"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision frontend is a stub; input_specs() supplies
precomputed patch embeddings (B, S, d_model) plus (B, S, 3) M-RoPE
position streams (temporal/height/width).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), embed_inputs=False,
)

def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, sparse_block=64, attn_block=64,
        attn_chunk=128, dtype="float32", mrope_sections=(8, 12, 12),
    )
