"""Config system: architecture + shape + run configs.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``
(exact paper/HF numbers) plus a reduced ``smoke()`` twin of the same family.
Shapes are the assignment's four (seq_len, global_batch) points.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "GroupSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """A run of structurally identical layers (scanned together).

    ``param_key`` names the parameter subtree; shared groups (e.g. zamba2's
    shared attention block) reuse the same key at several positions.
    """

    kind: str  # "dense" | "moe" | "ssm" | "shared_attn"
    count: int
    param_key: str
    shared: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (half-dim splits)
    # io
    embed_inputs: bool = True  # False: input_specs provides embeddings (stub frontend)
    tie_embeddings: bool = False
    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0
    moe_first_dense: int = 0
    moe_dense_ff: int = 0  # d_ff of the leading dense layers (0 -> d_ff)
    moe_capacity_factor: float = 1.25
    moe_routing_groups: int = 1  # set by launcher to #data shards
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # hybrid (zamba2): one shared attention block after every N ssm layers
    attn_every: int = 0
    # pixelfly
    sparse: bool = False
    sparse_density: float = 0.2
    sparse_block: int = 128
    lowrank_frac: float = 0.25
    sparse_attention: bool = False
    attn_local_blocks: int = 2
    attn_global_blocks: int = 1
    attn_max_stride: int = 0  # 0 -> full butterfly on the block grid
    attn_block: int = 128
    # numerics / runtime
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024  # kv chunk for the memory-efficient dense path
    # launcher-set distribution knobs (0/() => no sharding constraints,
    # e.g. single-device smoke tests)
    tp_size: int = 0
    batch_axes: tuple[str, ...] = ()

    # ------------------------------------------------------------------
    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP over 16 always divides."""
        return int(math.ceil(self.vocab_size / 256) * 256)

    def layer_groups(self) -> list[GroupSpec]:
        if self.family in ("dense", "vlm", "audio"):
            return [GroupSpec("dense", self.num_layers, "dense_0")]
        if self.family == "moe":
            groups = []
            if self.moe_first_dense:
                groups.append(GroupSpec("dense", self.moe_first_dense, "dense_0"))
            groups.append(
                GroupSpec("moe", self.num_layers - self.moe_first_dense, "moe_0")
            )
            return groups
        if self.family == "ssm":
            return [GroupSpec("ssm", self.num_layers, "ssm_0")]
        if self.family == "hybrid":
            if not self.attn_every:
                raise ValueError("hybrid family needs attn_every")
            groups: list[GroupSpec] = []
            n_cycles = self.num_layers // self.attn_every
            per = self.attn_every - 1
            for c in range(n_cycles):
                groups.append(GroupSpec("ssm", per, f"ssm_{c}"))
                groups.append(
                    GroupSpec("shared_attn", 1, "shared_attn", shared=True)
                )
            rem = self.num_layers - n_cycles * self.attn_every
            if rem:
                groups.append(GroupSpec("ssm", rem, f"ssm_{n_cycles}"))
            return groups
        raise ValueError(f"unknown family {self.family}")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
