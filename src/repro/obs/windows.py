"""Rolling-window views over a :class:`MetricsRegistry`.

The hot path records into plain counters and raw-sample histograms
(`repro.serving.stats`); nothing there knows about time windows.  This
module adds the *live* view on top without touching a single record
call: a :class:`WindowedView` periodically ``tick()``s, diffs the
registry against per-metric cursors (counter values, histogram sample
counts — histogram appends are the only hot-path writes, and a list
slice of the new tail is cheap), and files the deltas into a
time-bucketed ring.  Queries then answer "over the last N seconds":
rates from counter deltas, exact percentiles from the retained raw
sub-samples (never bucket interpolation — a window covering the whole
run reproduces ``stats_summary()``'s percentiles exactly).

Registry identity is part of the protocol: ``Engine.reset_stats()``
swaps in a *fresh* registry object, which semantically restarts the
measurement window — ``tick()`` detects the identity change, drops the
retained buckets and re-seeds the cursors, so a pre-reset sample can
never leak into a post-reset percentile.

Everything here runs on the caller's thread (the engine ticks once per
step, outside the jit'd programs); with monitoring off the engine never
constructs a view, so the off path does zero window work.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Callable, Hashable

import numpy as np

from .metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["Ewma", "WindowedView", "merged_percentile"]


class Ewma:
    """Exponentially-weighted moving average (fixed ``alpha`` per
    update, no wall-clock dependence — callers update at their own
    cadence).  ``value`` is 0.0 until the first update; ``n`` counts
    updates so consumers can require a warmup."""

    def __init__(self, alpha: float = 0.2):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self._v: float | None = None
        self.n = 0

    def update(self, v: float) -> float:
        self._v = (
            float(v)
            if self._v is None
            else self.alpha * float(v) + (1.0 - self.alpha) * self._v
        )
        self.n += 1
        return self._v

    @property
    def value(self) -> float:
        return 0.0 if self._v is None else self._v


class _Bucket:
    __slots__ = ("start", "counts", "samples")

    def __init__(self, start: float):
        self.start = start
        # key: metric name, or (name, str(label)) for labeled counters
        self.counts: dict[Hashable, int | float] = {}
        self.samples: dict[str, list[float]] = {}


class WindowedView:
    """Time-bucketed ring of registry deltas.

    ``registry_fn`` is re-evaluated every tick (the engine passes
    ``lambda: self.metrics``) so the view follows ``reset_stats()``'s
    registry swap.  ``window_s`` is the retention horizon, divided into
    ``n_buckets`` sub-buckets — the resolution of any span-limited
    query (a "last 5 s" rate actually covers the buckets overlapping
    the last 5 s, i.e. up to one bucket width more).
    """

    def __init__(
        self,
        registry_fn: Callable[[], MetricsRegistry],
        *,
        window_s: float = 30.0,
        n_buckets: int = 15,
        now_fn: Callable[[], float] = time.monotonic,
    ):
        if window_s <= 0 or n_buckets < 1:
            raise ValueError("window_s must be > 0 and n_buckets >= 1")
        self._registry_fn = registry_fn
        self._now = now_fn
        self.window_s = float(window_s)
        self.n_buckets = int(n_buckets)
        self.bucket_s = self.window_s / self.n_buckets
        self._buckets: deque[_Bucket] = deque()
        self._cursors: dict[Hashable, int | float] = {}
        self._gauges: dict[str, int | float] = {}
        self._reg: MetricsRegistry | None = None
        self._last_now = 0.0

    # ---- recording (one call per engine step) ------------------------
    def tick(self, now: float | None = None) -> None:
        reg = self._registry_fn()
        if reg is not self._reg:
            # reset_stats() swapped the registry: the measurement window
            # restarted from zero — retained history is for dead metrics
            self._reg = reg
            self._cursors.clear()
            self._buckets.clear()
            self._gauges.clear()
        now = self._now() if now is None else float(now)
        self._last_now = now
        self._roll(now)
        cur = self._buckets[-1]
        for m in reg.collect():
            if isinstance(m, Counter):
                self._take(cur, m.name, m.value)
                for lab, v in m.items():
                    self._take(cur, (m.name, str(lab)), v)
            elif isinstance(m, Histogram):
                c = int(self._cursors.get(m.name, 0))
                n = len(m.samples)
                if n > c:
                    cur.samples.setdefault(m.name, []).extend(
                        m.samples[c:n]
                    )
                    self._cursors[m.name] = n
                elif n < c:  # histogram shrank (shouldn't happen): resync
                    self._cursors[m.name] = n
            elif isinstance(m, Gauge):
                self._gauges[m.name] = m.value

    def _take(self, bucket: _Bucket, key: Hashable, total) -> None:
        prev = self._cursors.get(key, 0)
        if total != prev:
            delta = total - prev
            if delta > 0:  # counters are monotonic; guard anyway
                bucket.counts[key] = bucket.counts.get(key, 0) + delta
            self._cursors[key] = total

    def _roll(self, now: float) -> None:
        if not self._buckets:
            self._buckets.append(_Bucket(now))
            return
        last = self._buckets[-1]
        if now - last.start >= self.window_s + self.bucket_s:
            # ticks stalled for longer than the whole window: everything
            # retained has aged out — restart rather than spinning
            # through hundreds of empty buckets
            self._buckets.clear()
            self._buckets.append(_Bucket(now))
            return
        while now - self._buckets[-1].start >= self.bucket_s:
            self._buckets.append(
                _Bucket(self._buckets[-1].start + self.bucket_s)
            )
        cutoff = now - self.window_s
        while len(self._buckets) > 1 and (
            self._buckets[0].start + self.bucket_s <= cutoff
        ):
            self._buckets.popleft()

    # ---- queries -----------------------------------------------------
    def _included(self, span_s: float | None) -> list[_Bucket]:
        if span_s is None:
            return list(self._buckets)
        cutoff = self._last_now - float(span_s)
        return [
            b for b in self._buckets if b.start + self.bucket_s > cutoff
        ]

    @property
    def covered_s(self) -> float:
        """Wall seconds the retained buckets actually span."""
        if not self._buckets:
            return 0.0
        return max(0.0, self._last_now - self._buckets[0].start)

    def delta(
        self,
        name: str,
        span_s: float | None = None,
        *,
        label: str | None = None,
    ) -> int | float:
        """Counter increase over the window (per-label with ``label``)."""
        key: Hashable = name if label is None else (name, label)
        return sum(b.counts.get(key, 0) for b in self._included(span_s))

    def rate(self, name: str, span_s: float | None = None) -> float:
        """Counter increase per second over the (covered part of the)
        window; 0.0 before the first tick."""
        bs = self._included(span_s)
        if not bs:
            return 0.0
        covered = self._last_now - bs[0].start
        if covered <= 0.0:
            return 0.0
        return float(sum(b.counts.get(name, 0) for b in bs)) / covered

    def samples(
        self, name: str, span_s: float | None = None
    ) -> list[float]:
        out: list[float] = []
        for b in self._included(span_s):
            s = b.samples.get(name)
            if s:
                out.extend(s)
        return out

    def percentile(
        self, name: str, q: float, span_s: float | None = None
    ) -> float:
        """Exact percentile over the window's raw samples (0.0 when the
        window holds none — same empty convention as ``Histogram``)."""
        s = self.samples(name, span_s)
        if not s:
            return 0.0
        return float(np.percentile(np.asarray(s, np.float64), q))

    def gauge(self, name: str, default: int | float = 0) -> int | float:
        """Last value a tick saw for a gauge."""
        return self._gauges.get(name, default)


def merged_percentile(
    views: list[WindowedView], name: str, q: float,
    span_s: float | None = None,
) -> float:
    """Fleet percentile over several views' raw window samples (true
    percentile over the concatenation, not an average of averages —
    the same policy as ``MetricsRegistry.merged``)."""
    s: list[float] = []
    for v in views:
        s.extend(v.samples(name, span_s))
    if not s:
        return 0.0
    return float(np.percentile(np.asarray(s, np.float64), q))
