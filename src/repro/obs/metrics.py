"""Typed metrics registry: counters, gauges, log-bucketed histograms.

The serving stats objects (`ServeStats` / `SwapStats` / `PrefixStats`)
are views over one shared :class:`MetricsRegistry` per engine.  Two
consumers read the same registry:

  * ``stats_summary()`` — the benchmark-facing dict, which needs *exact*
    values (integer counters stay ints, percentiles come from the raw
    samples, never from bucket interpolation, so BENCH trajectories
    don't move under the refactor);
  * ``repro.obs.prom`` — the Prometheus text exposition, which needs
    the conventional ``_total`` counters and cumulative ``le`` buckets.

Histograms therefore keep **both** the raw sample list (bounded only by
traffic; the engine resets per measurement window) and log-spaced
cumulative buckets.  Registries merge (`MetricsRegistry.merged`) for
replica aggregation: counters add, gauges add, histogram samples
concatenate — so a merged percentile is the true percentile over all
replicas' samples, not an average of averages.

Metric names follow Prometheus conventions (``snake_case``, counters
end in ``_total``, unit suffixes like ``_seconds``).  Label *keys* may
be arbitrary hashables host-side (the prefill bucket label is an
``(N, S)`` tuple so summaries can sort numerically); the prom exporter
stringifies them.
"""

from __future__ import annotations

import math
from typing import Hashable, Iterable

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_buckets",
]


def default_buckets(
    lo: float = 1e-4, hi: float = 64.0, factor: float = 4.0
) -> tuple[float, ...]:
    """Log-spaced bucket upper bounds: lo, lo*factor, ... >= hi.

    The default spans 100 µs .. 64 s in decade-ish steps — wide enough
    for TTFT and queue wait, cheap enough (10 buckets) that ``observe``
    stays a bisect plus one increment.
    """
    bounds = []
    b = lo
    while b < hi:
        bounds.append(b)
        b *= factor
    bounds.append(hi)
    return tuple(bounds)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help


class Counter(_Metric):
    """Monotonic counter, optionally labeled.

    Unlabeled use: ``c.inc()`` / ``c.value``.  Labeled use:
    ``c.inc(3, label=key)`` / ``c.get(key)`` / ``c.items()``.
    Increments preserve Python numeric types (int stays int) so the
    summary dicts keep their exact pre-refactor JSON shapes.
    """

    def __init__(self, name: str, help: str, labelname: str | None = None):
        super().__init__(name, help)
        self.labelname = labelname
        self._value = 0
        self._by_label: dict[Hashable, int | float] = {}

    def inc(self, n: int | float = 1, label: Hashable = None) -> None:
        if n < 0:
            raise ValueError(
                f"counter {self.name} cannot decrease (inc({n}))"
            )
        if label is None:
            self._value += n
        else:
            self._by_label[label] = self._by_label.get(label, 0) + n

    @property
    def value(self) -> int | float:
        # base + labeled: a counter inc'd both ways (or merged from a
        # mixed pair of registries) must not silently drop the unlabeled
        # part. sum() of an empty dict is int 0, preserving int-ness.
        return self._value + sum(self._by_label.values())

    def get(self, label: Hashable) -> int | float:
        return self._by_label.get(label, 0)

    def items(self) -> list[tuple[Hashable, int | float]]:
        return list(self._by_label.items())

    def _merge_from(self, other: "Counter") -> None:
        self._value += other._value
        for k, v in other._by_label.items():
            self._by_label[k] = self._by_label.get(k, 0) + v


class Gauge(_Metric):
    """Last-set value; merge sums (occupancy-style gauges are per-replica
    resource counts, and the merged registry reports fleet totals)."""

    def __init__(self, name: str, help: str):
        super().__init__(name, help)
        self._value: int | float = 0

    def set(self, v: int | float) -> None:
        self._value = v

    def inc(self, n: int | float = 1) -> None:
        self._value += n

    @property
    def value(self) -> int | float:
        return self._value

    def _merge_from(self, other: "Gauge") -> None:
        self._value += other._value


class Histogram(_Metric):
    """Raw-sample histogram with parallel log buckets.

    ``observe`` appends the raw value (exact percentiles for the
    summary) and bumps the first bucket whose bound >= v (cumulative
    counts for the prom exposition).

    Zero-sample contract: the live ``/metrics`` endpoint scrapes
    registries *before* the first request lands, so every statistic is
    well-defined on an empty histogram — ``percentile``/``mean``/
    ``min``/``max`` return 0.0 (never NaN, never raise) and the prom
    exposition renders all-zero bucket/sum/count series.
    """

    def __init__(
        self,
        name: str,
        help: str,
        buckets: Iterable[float] | None = None,
    ):
        super().__init__(name, help)
        self.bounds = tuple(buckets) if buckets is not None else default_buckets()
        self._bucket_counts = [0] * (len(self.bounds) + 1)  # +Inf tail
        self.samples: list[float] = []
        self._sum = 0.0

    def observe(self, v: float) -> None:
        self.samples.append(v)
        self._sum += v
        lo, hi = 0, len(self.bounds)
        while lo < hi:  # bisect_left over bounds
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self._bucket_counts[lo] += 1

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples, np.float64), q))

    def mean(self) -> float:
        return self._sum / len(self.samples) if self.samples else 0.0

    def min(self) -> float:
        return min(self.samples) if self.samples else 0.0

    def max(self) -> float:
        return max(self.samples) if self.samples else 0.0

    def cumulative_buckets(self) -> list[tuple[float, int]]:
        """(upper_bound, cumulative_count) pairs, +Inf last."""
        out, acc = [], 0
        for bound, n in zip(self.bounds, self._bucket_counts):
            acc += n
            out.append((bound, acc))
        out.append((math.inf, acc + self._bucket_counts[-1]))
        return out

    def _merge_from(self, other: "Histogram") -> None:
        self.samples.extend(other.samples)
        self._sum += other._sum
        if other.bounds == self.bounds:
            for i, n in enumerate(other._bucket_counts):
                self._bucket_counts[i] += n
        else:  # rebucket through observe-equivalent path
            for v in other.samples:
                lo, hi = 0, len(self.bounds)
                while lo < hi:
                    mid = (lo + hi) // 2
                    if self.bounds[mid] < v:
                        lo = mid + 1
                    else:
                        hi = mid
                self._bucket_counts[lo] += 1


class MetricsRegistry:
    """Ordered name -> metric map with get-or-create registration.

    ``counter``/``gauge``/``histogram`` return the existing metric when
    the name is already registered (with a kind check), so stats *views*
    can bind to a merged registry without re-creating anything.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, *args, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}"
                )
            return m
        m = cls(name, *args, **kw)
        self._metrics[name] = m
        return m

    def counter(
        self, name: str, help: str = "", labelname: str | None = None
    ) -> Counter:
        return self._get_or_create(Counter, name, help, labelname)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self,
        name: str,
        help: str = "",
        buckets: Iterable[float] | None = None,
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def collect(self) -> list[_Metric]:
        return list(self._metrics.values())

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> _Metric:
        return self._metrics[name]

    def merge_from(self, other: "MetricsRegistry") -> None:
        """Fold ``other``'s metrics into this registry (sum counters and
        gauges, concatenate histogram samples)."""
        for m in other.collect():
            mine = self._metrics.get(m.name)
            if mine is None:
                if isinstance(m, Counter):
                    mine = self.counter(m.name, m.help, m.labelname)
                elif isinstance(m, Gauge):
                    mine = self.gauge(m.name, m.help)
                else:
                    mine = self.histogram(m.name, m.help, buckets=m.bounds)
            mine._merge_from(m)

    @classmethod
    def merged(cls, registries: Iterable["MetricsRegistry"]) -> "MetricsRegistry":
        out = cls()
        for reg in registries:
            out.merge_from(reg)
        return out
