"""Flight recorder: anomaly-triggered incident bundles.

The tracer ring already holds "what the engine just did" and the
metrics registry holds "what it added up to" — but both are gone by the
time someone asks what happened before a latency spike.  The flight
recorder snapshots them *at the anomaly*: one timestamped directory per
incident containing

    manifest.json   kind, step, wall time, engine config, free context
                    (SLO state, the spike's measurements, ...)
    metrics.prom    Prometheus text snapshot (``obs/prom.render``)
    trace.json      the tracer ring as Chrome trace-event JSON — only
                    when tracing is on; always passes
                    ``validate_trace_file`` (open spans are closed as
                    truncated by the exporter, counter tracks ride
                    along)

Trigger policy lives with the caller (the engine fires on step-time
spikes vs a warm EWMA, on post-warmup step compiles — the DispatchGuard
invariant tripping — and on SLO CRITICAL transitions);
:class:`SpikeDetector` is the reusable spike half.  The recorder itself
only enforces *debounce*: per-kind ``min_interval_s`` plus the
detector's cooldown mean one sustained anomaly produces one bundle, not
one per step.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from .prom import render
from .windows import Ewma

__all__ = ["SpikeDetector", "FlightRecorder"]


class SpikeDetector:
    """EWMA-baseline spike detection for a scalar step signal.

    ``observe(v)`` returns True when ``v`` exceeds ``factor`` times the
    warm EWMA baseline (at least ``min_samples`` prior observations and
    ``v >= min_value`` — an absolute floor so microsecond-noise on tiny
    models can't trip it).  A firing arms a ``cooldown``-observation
    refractory period, and the spike itself is folded into the EWMA
    (a *sustained* regression raises the baseline and becomes the new
    normal instead of firing forever)."""

    def __init__(
        self,
        *,
        factor: float = 8.0,
        alpha: float = 0.2,
        min_samples: int = 16,
        cooldown: int = 32,
        min_value: float = 0.0,
    ):
        if factor <= 1.0:
            raise ValueError("factor must be > 1")
        if min_samples < 1 or cooldown < 0:
            raise ValueError("min_samples >= 1, cooldown >= 0")
        self.factor = factor
        self.min_samples = min_samples
        self.cooldown = cooldown
        self.min_value = min_value
        self.ewma = Ewma(alpha)
        self._cool = 0
        self.fired = 0

    @property
    def baseline(self) -> float:
        return self.ewma.value

    def observe(self, v: float) -> bool:
        fire = (
            self._cool == 0
            and self.ewma.n >= self.min_samples
            and v >= self.min_value
            and v > self.factor * self.ewma.value
        )
        if fire:
            self.fired += 1
            self._cool = self.cooldown
        elif self._cool:
            self._cool -= 1
        self.ewma.update(v)
        return fire


class FlightRecorder:
    """Writes incident bundles under ``out_dir``.

    ``capture()`` returns the bundle path, or None when the per-kind
    debounce (``min_interval_s``) or the global ``max_bundles`` cap
    suppressed it — a flood of anomalies degrades to a bounded set of
    bundles, never unbounded disk growth."""

    def __init__(
        self,
        out_dir: str,
        *,
        min_interval_s: float = 1.0,
        max_bundles: int = 64,
        clock=time.monotonic,
    ):
        self.out_dir = Path(out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.min_interval_s = float(min_interval_s)
        self.max_bundles = int(max_bundles)
        self._clock = clock
        self._last: dict[str, float] = {}
        self._seq = 0
        self.incidents: list[str] = []

    def capture(
        self,
        kind: str,
        *,
        tracer=None,
        metrics=None,
        config: dict | None = None,
        context: dict | None = None,
    ) -> str | None:
        now = self._clock()
        last = self._last.get(kind)
        if last is not None and now - last < self.min_interval_s:
            return None
        if len(self.incidents) >= self.max_bundles:
            return None
        self._last[kind] = now
        self._seq += 1
        stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
        bundle = self.out_dir / f"incident-{stamp}-{self._seq:03d}-{kind}"
        bundle.mkdir(parents=True, exist_ok=True)
        manifest = {
            "kind": kind,
            "seq": self._seq,
            "captured_unix_s": time.time(),
            "config": config or {},
            "context": context or {},
            "files": ["manifest.json"],
        }
        if metrics is not None:
            (bundle / "metrics.prom").write_text(render(metrics))
            manifest["files"].append("metrics.prom")
        if tracer is not None and getattr(tracer, "enabled", False):
            # local import: flight must stay importable without the
            # exporter having been touched (and avoids a cycle)
            from .perfetto import export_perfetto

            export_perfetto({0: tracer}, str(bundle / "trace.json"))
            manifest["files"].append("trace.json")
        with open(bundle / "manifest.json", "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.write("\n")
        path = str(bundle)
        self.incidents.append(path)
        return path
