"""Observability for the serving stack: tracing spans + typed metrics.

Three pieces, deliberately dependency-free (numpy only):

  * :mod:`repro.obs.trace` — ring-buffered, ``perf_counter_ns``-stamped
    span tracer (``Tracer`` / ``NULL_TRACER``); the engine records
    per-request lifecycle spans and per-step phase spans through it.
  * :mod:`repro.obs.metrics` — ``MetricsRegistry`` of counters, gauges
    and raw-sample histograms; ``ServeStats`` / ``SwapStats`` /
    ``PrefixStats`` are views over one engine-owned registry.
  * Exporters: :mod:`repro.obs.perfetto` (Chrome trace-event JSON for
    ui.perfetto.dev) and :mod:`repro.obs.prom` (Prometheus text
    exposition).

Everything here is host-side.  Calling a recorder from inside a jit'd
function records a tracer-time constant, not a runtime value — jaxlint
rule JL006 flags that statically.
"""

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .perfetto import (
    export_perfetto,
    validate_trace,
    validate_trace_file,
)
from .trace import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "export_perfetto",
    "validate_trace",
    "validate_trace_file",
]
