"""Observability for the serving stack: tracing spans + typed metrics.

Three pieces, deliberately dependency-free (numpy only):

  * :mod:`repro.obs.trace` — ring-buffered, ``perf_counter_ns``-stamped
    span tracer (``Tracer`` / ``NULL_TRACER``); the engine records
    per-request lifecycle spans and per-step phase spans through it.
  * :mod:`repro.obs.metrics` — ``MetricsRegistry`` of counters, gauges
    and raw-sample histograms; ``ServeStats`` / ``SwapStats`` /
    ``PrefixStats`` are views over one engine-owned registry.
  * Exporters: :mod:`repro.obs.perfetto` (Chrome trace-event JSON for
    ui.perfetto.dev) and :mod:`repro.obs.prom` (Prometheus text
    exposition).
  * The live plane: :mod:`repro.obs.windows` (rolling-window views
    over a registry), :mod:`repro.obs.slo` (multi-window burn-rate
    monitor), :mod:`repro.obs.flight` (anomaly-triggered incident
    bundles) and :mod:`repro.obs.http` (the ``/metrics`` / ``/healthz``
    / ``/slo`` / ``/vars`` scrape endpoint).

Everything here is host-side.  Calling a recorder from inside a jit'd
function records a tracer-time constant, not a runtime value — jaxlint
rule JL006 flags that statically.
"""

from .flight import FlightRecorder, SpikeDetector
from .http import MetricsServer, attach
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .perfetto import (
    export_perfetto,
    validate_trace,
    validate_trace_file,
)
from .slo import CRITICAL, OK, WARN, BurnRateMonitor, SloConfig
from .trace import NULL_TRACER, NullTracer, Tracer
from .windows import Ewma, WindowedView

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "export_perfetto",
    "validate_trace",
    "validate_trace_file",
    "Ewma",
    "WindowedView",
    "SloConfig",
    "BurnRateMonitor",
    "OK",
    "WARN",
    "CRITICAL",
    "SpikeDetector",
    "FlightRecorder",
    "MetricsServer",
    "attach",
]
