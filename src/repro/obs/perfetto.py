"""Chrome trace-event (Perfetto-compatible) JSON export + validation.

``export_perfetto({pid: tracer}, path)`` writes the classic JSON trace
format — ``{"traceEvents": [...]}`` with ``B``/``E``/``I``/``X`` span
phases plus ``C`` counter samples (pool occupancy, queue depth, running
slots render as counter lanes under the spans) — that ui.perfetto.dev
and ``chrome://tracing`` both load.  Each tracer
becomes one process (replica index as ``pid``); each tracer track (one
per slot, one per engine phase, one for the queue) becomes one thread
with a ``thread_name`` metadata record, so the timeline renders as
labeled lanes.

The exporter is also where ring-wrap damage is repaired: events are
emitted in timestamp order, orphaned ``E``s (their ``B`` overwritten by
wrap) are dropped, and spans still open at export time are closed with
a synthetic ``E`` carrying ``"truncated": true`` — the emitted file
always satisfies :func:`validate_trace_file`, which `scripts/tier1.sh`
runs against the benchmark's ``--trace-out`` output.
"""

from __future__ import annotations

import json
from typing import TYPE_CHECKING, Mapping

from .trace import KIND_B, KIND_C, KIND_E, KIND_I, KIND_X

if TYPE_CHECKING:  # pragma: no cover
    from .trace import Tracer

__all__ = ["export_perfetto", "validate_trace", "validate_trace_file"]


class TraceValidationError(ValueError):
    pass


def _tracer_events(pid: int, tracer: "Tracer") -> list[dict]:
    """One tracer -> trace-event dicts (ts in µs, per Chrome schema)."""
    raw = sorted(tracer.events(), key=lambda e: e["ts_ns"])
    out: list[dict] = []
    tids: dict[str, int] = {}
    # Stable, human-meaningful lane order: the tracer interned engine
    # phases first, then slots, then the queue (Engine.__init__ order).
    for label in tracer._track_labels:
        tids[label] = len(tids)
    open_spans: dict[int, list[dict]] = {t: [] for t in tids.values()}
    max_ts = 0
    for ev in raw:
        tid = tids[ev["track"]]
        ts_us = ev["ts_ns"] / 1e3
        max_ts = max(max_ts, ev["ts_ns"] + ev["dur_ns"])
        args = {"a0": ev["a0"], "a1": ev["a1"]}
        if ev["kind"] == KIND_B:
            rec = {
                "ph": "B", "pid": pid, "tid": tid, "ts": ts_us,
                "name": ev["name"], "args": args,
            }
            out.append(rec)
            open_spans[tid].append(rec)
        elif ev["kind"] == KIND_E:
            if not open_spans[tid]:
                continue  # B lost to ring wrap: drop the orphan E
            open_spans[tid].pop()
            out.append(
                {
                    "ph": "E", "pid": pid, "tid": tid, "ts": ts_us,
                    "name": ev["name"], "args": args,
                }
            )
        elif ev["kind"] == KIND_I:
            out.append(
                {
                    "ph": "I", "pid": pid, "tid": tid, "ts": ts_us,
                    "name": ev["name"], "s": "t", "args": args,
                }
            )
        elif ev["kind"] == KIND_X:
            out.append(
                {
                    "ph": "X", "pid": pid, "tid": tid, "ts": ts_us,
                    "dur": ev["dur_ns"] / 1e3, "name": ev["name"],
                    "args": args,
                }
            )
        elif ev["kind"] == KIND_C:
            # counter sample: args carries the series value (Perfetto
            # renders each C name as its own counter lane)
            out.append(
                {
                    "ph": "C", "pid": pid, "tid": tid, "ts": ts_us,
                    "name": ev["name"], "args": {"value": ev["a0"]},
                }
            )
    # Close spans still open at export with a truncated-flagged E so
    # every B in the file pairs (live decode spans mid-traffic, or spans
    # force-closed conceptually by reset before their end() ran).
    end_us = max(max_ts, 1) / 1e3
    for tid in sorted(open_spans):
        for rec in reversed(open_spans[tid]):
            out.append(
                {
                    "ph": "E", "pid": pid, "tid": tid, "ts": end_us,
                    "name": rec["name"], "args": {"truncated": True},
                }
            )
    meta = []
    for label, tid in tids.items():
        meta.append(
            {
                "ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                "args": {"name": label},
            }
        )
    meta.append(
        {
            "ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": f"replica{pid}"},
        }
    )
    return meta + out


def export_perfetto(tracers: "Mapping[int, Tracer]", path: str) -> int:
    """Write tracers (pid -> tracer, one per replica) to ``path`` as
    Chrome trace-event JSON.  Returns the number of non-metadata events
    written."""
    events: list[dict] = []
    for pid in sorted(tracers):
        events.extend(_tracer_events(pid, tracers[pid]))
    payload = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(payload, f)
    return sum(e["ph"] != "M" for e in events)


# ---------------------------------------------------------------------------
# Validation — run by tests and by the tier-1 trace round-trip leg.
# ---------------------------------------------------------------------------


def validate_trace(payload: dict) -> dict:
    """Validate a trace-event payload; returns summary stats.

    Checks (each failure raises :class:`TraceValidationError`):
      * top level is ``{"traceEvents": [...]}`` with dict events;
      * per (pid, tid) track, non-metadata event ``ts`` are monotonic
        non-decreasing in file order;
      * per track, ``B``/``E`` pairs match by name, properly nested,
        with no unmatched event left at end of file;
      * ``C`` counter samples carry a numeric args value and, per
        (pid, tid, name) counter series, non-decreasing timestamps
        (the per-track check would let two interleaved series hide a
        regression; the per-series check would not);
      * every track with events has a ``thread_name`` metadata record;
      * at least one slot track (thread name ``slot*``) has events.
    """
    if not isinstance(payload, dict) or not isinstance(
        payload.get("traceEvents"), list
    ):
        raise TraceValidationError("missing traceEvents list")
    events = payload["traceEvents"]
    track_names: dict[tuple, str] = {}
    last_ts: dict[tuple, float] = {}
    stacks: dict[tuple, list[str]] = {}
    counts: dict[tuple, int] = {}
    counter_ts: dict[tuple, float] = {}
    n_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise TraceValidationError(f"event {i} is not a trace event")
        key = (ev.get("pid", 0), ev.get("tid", 0))
        if ev["ph"] == "M":
            if ev.get("name") == "thread_name":
                track_names[key] = ev["args"]["name"]
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise TraceValidationError(f"event {i}: bad ts {ts!r}")
        if ts < last_ts.get(key, 0.0):
            raise TraceValidationError(
                f"event {i}: ts not monotonic on track {key} "
                f"({ts} < {last_ts[key]})"
            )
        last_ts[key] = ts
        counts[key] = counts.get(key, 0) + 1
        stack = stacks.setdefault(key, [])
        if ev["ph"] == "B":
            stack.append(ev["name"])
        elif ev["ph"] == "E":
            if not stack:
                raise TraceValidationError(
                    f"event {i}: E {ev.get('name')!r} with no open B on "
                    f"track {key}"
                )
            top = stack.pop()
            if top != ev["name"]:
                raise TraceValidationError(
                    f"event {i}: E {ev['name']!r} closes B {top!r} on "
                    f"track {key}"
                )
            n_spans += 1
        elif ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise TraceValidationError(f"event {i}: X without dur")
            n_spans += 1
        elif ev["ph"] == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                isinstance(v, (int, float)) and not isinstance(v, bool)
                for v in args.values()
            ):
                raise TraceValidationError(
                    f"event {i}: C without numeric args values"
                )
            series = (*key, ev.get("name"))
            if ts < counter_ts.get(series, 0.0):
                raise TraceValidationError(
                    f"event {i}: counter ts not monotonic on series "
                    f"{series} ({ts} < {counter_ts[series]})"
                )
            counter_ts[series] = ts
        elif ev["ph"] not in ("I", "i"):
            raise TraceValidationError(f"event {i}: unknown phase {ev['ph']!r}")
    for key, stack in stacks.items():
        if stack:
            raise TraceValidationError(
                f"unclosed span(s) {stack!r} on track {key}"
            )
    for key in counts:
        if key not in track_names:
            raise TraceValidationError(f"track {key} has no thread_name")
    slot_tracks = [
        k for k, n in track_names.items()
        if n.startswith("slot") and counts.get(k, 0)
    ]
    if counts and not slot_tracks:
        raise TraceValidationError("no nonempty slot track")
    return {
        "events": sum(counts.values()),
        "tracks": len(counts),
        "spans": n_spans,
        "slot_tracks": len(slot_tracks),
        "counter_series": len(counter_ts),
    }


def validate_trace_file(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return validate_trace(payload)
