"""Live scrape endpoint: a stdlib ``ThreadingHTTPServer`` over the
observability plane.

Four routes, all GET, all read-only:

    /metrics   Prometheus text exposition (``obs/prom.render``)
    /healthz   liveness — ``ok`` and 200 while the server thread runs
    /slo       burn-rate monitor status as JSON (404 when no monitor)
    /vars      windowed live stats as JSON (404 when no monitor)

Thread-safety contract with the engine: every request re-evaluates
``registry_fn()`` and renders from whatever registry object it returns.
``Engine.reset_stats()`` *swaps* the registry attribute atomically (one
Python attribute store), so a concurrent scrape renders either the old
or the new registry — always a self-consistent object, never a torn
mix.  Histogram appends racing a render can at worst make ``_count``
lag ``_sum`` by the in-flight sample; the exposition stays parseable
(the tier-1 leg scrapes mid-decode and round-trips ``prom.parse``).

``attach()`` duck-types the served object: an ``Engine`` (``.metrics``)
or a ``ReplicaRouter`` (``.merged_metrics()`` — scrapes aggregate the
fleet), picking up ``windowed_vars``/``slo_state`` when present.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable

from .prom import render

__all__ = ["MetricsServer", "attach", "split_listen"]


def split_listen(listen: str) -> tuple[str, int]:
    """``"HOST:PORT"`` -> (host, port); port 0 binds an ephemeral port
    (the server reports the real one)."""
    host, sep, port = listen.rpartition(":")
    if not sep or not host:
        raise ValueError(
            f"--listen expects HOST:PORT, got {listen!r}"
        )
    return host, int(port)


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-obs/1"
    protocol_version = "HTTP/1.1"

    def log_message(self, *args) -> None:  # quiet: no per-scrape stderr
        return

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (stdlib handler API)
        path = self.path.split("?", 1)[0]
        route = self.server.routes.get(path)  # type: ignore[attr-defined]
        if route is None:
            self._send(404, b"not found\n", "text/plain; charset=utf-8")
            return
        fn, ctype = route
        try:
            body = fn()
        except Exception as e:  # never kill the serving thread
            self._send(
                500,
                f"internal error: {e}\n".encode(),
                "text/plain; charset=utf-8",
            )
            return
        if isinstance(body, str):
            body = body.encode()
        self._send(200, body, ctype)


class MetricsServer:
    """Daemon-threaded scrape endpoint bound to ``host:port`` (port 0
    -> ephemeral; read the bound one back from ``.port`` / ``.url``)."""

    _PROM = "text/plain; version=0.0.4; charset=utf-8"
    _JSON = "application/json; charset=utf-8"

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        registry_fn: Callable,
        vars_fn: Callable | None = None,
        slo_fn: Callable | None = None,
    ):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        routes: dict[str, tuple[Callable, str]] = {
            "/metrics": (lambda: render(registry_fn()), self._PROM),
            "/healthz": (lambda: "ok\n", "text/plain; charset=utf-8"),
        }
        if vars_fn is not None:
            routes["/vars"] = (
                lambda: json.dumps(vars_fn(), sort_keys=True) + "\n",
                self._JSON,
            )
        if slo_fn is not None:
            routes["/slo"] = (
                lambda: json.dumps(slo_fn(), sort_keys=True) + "\n",
                self._JSON,
            )
        self._httpd.routes = routes  # type: ignore[attr-defined]
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="repro-metrics-http",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def attach(served, listen: str = "127.0.0.1:0") -> MetricsServer:
    """Start a :class:`MetricsServer` over an ``Engine`` or a
    ``ReplicaRouter`` (``serve --listen HOST:PORT`` calls this)."""
    host, port = split_listen(listen)
    if hasattr(served, "merged_metrics"):
        registry_fn = served.merged_metrics
    else:
        registry_fn = lambda: served.metrics  # noqa: E731
    vars_fn = getattr(served, "windowed_vars", None)
    slo_fn = getattr(served, "slo_state", None)
    return MetricsServer(
        host,
        port,
        registry_fn=registry_fn,
        vars_fn=vars_fn,
        slo_fn=slo_fn,
    ).start()
