"""Ring-buffered span tracer for the serving hot path.

Design constraints, in order:

  1. **Cheap when on.**  One event = one write into preallocated numpy
     columns (ts/kind/name/track/a0/a1) at a wrapping cursor, stamped
     with ``perf_counter_ns``.  Track and name labels are interned to
     small ints up front (``Engine.__init__`` resolves every id it will
     ever use), so recording does no string work and no per-event
     allocation beyond the open-span stack push.
  2. **Free when off.**  :data:`NULL_TRACER` implements the same surface
     as pure no-ops, so engine code calls ``self.tracer.begin(...)``
     unconditionally — no ``if traced:`` branches on the hot path, and
     a disabled engine does zero obs work (tests assert this by
     patching :func:`perf_counter_ns` with a counting shim).
  3. **Consistent under reset and wrap.**  ``reset()`` closes all open
     spans (counted in ``truncated_spans``) *before* clearing the ring,
     so a mid-traffic ``Engine.reset_stats()`` never leaks a dangling
     ``B`` — subsequent ``end()`` calls for pre-reset spans are no-ops.
     Ring wrap drops the oldest events; the exporter re-pairs B/E per
     track and drops orphaned ``E``s whose ``B`` was overwritten.

Event model (mirrors the Chrome trace-event phases the exporter emits):
``B``/``E`` nested spans per track, ``I`` instants, ``X`` complete
events carrying an explicit (ts, dur) — used for queue-wait spans whose
start is the request's submit timestamp, recorded only at admission —
and ``C`` counter samples (a0 = the integer value; the exporter renders
them as Perfetto counter tracks under the spans, e.g. pool occupancy
and queue depth per step).
"""

from __future__ import annotations

from time import perf_counter_ns

import numpy as np

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "KIND_B", "KIND_E",
           "KIND_I", "KIND_X", "KIND_C"]

KIND_B, KIND_E, KIND_I, KIND_X, KIND_C = 0, 1, 2, 3, 4


class Tracer:
    enabled = True

    def __init__(self, capacity: int = 1 << 16):
        cap = 1
        while cap < max(2, int(capacity)):
            cap <<= 1  # power of two: wrap is a mask, not a modulo
        self._cap = cap
        self._mask = cap - 1
        self._ts = np.zeros(cap, np.int64)
        self._dur = np.zeros(cap, np.int64)
        self._kind = np.zeros(cap, np.int8)
        self._name = np.zeros(cap, np.int32)
        self._track = np.zeros(cap, np.int32)
        self._a0 = np.zeros(cap, np.int64)
        self._a1 = np.zeros(cap, np.int64)
        self._n = 0  # events ever recorded; ring holds the last `cap`
        self._track_labels: list[str] = []
        self._track_ids: dict[str, int] = {}
        self._name_labels: list[str] = []
        self._name_ids: dict[str, int] = {}
        # per-track stack of open span name-ids (B pushed, E pops)
        self._open: list[list[int]] = []
        self.truncated_spans = 0  # spans force-closed by reset()

    # -- interning -----------------------------------------------------

    def track(self, label: str) -> int:
        """Intern a track label -> id (one Perfetto thread per track)."""
        tid = self._track_ids.get(label)
        if tid is None:
            tid = len(self._track_labels)
            self._track_ids[label] = tid
            self._track_labels.append(label)
            self._open.append([])
        return tid

    def name(self, label: str) -> int:
        nid = self._name_ids.get(label)
        if nid is None:
            nid = len(self._name_labels)
            self._name_ids[label] = nid
            self._name_labels.append(label)
        return nid

    # -- recording (hot path) ------------------------------------------

    def _record(
        self, kind: int, track: int, name: int, ts: int, dur: int,
        a0: int, a1: int,
    ) -> None:
        i = self._n & self._mask
        self._ts[i] = ts
        self._dur[i] = dur
        self._kind[i] = kind
        self._name[i] = name
        self._track[i] = track
        self._a0[i] = a0
        self._a1[i] = a1
        self._n += 1

    def begin(self, track: int, name: int, a0: int = 0, a1: int = 0) -> int:
        """Open a span on ``track``; returns its start timestamp (ns)."""
        ts = perf_counter_ns()
        self._record(KIND_B, track, name, ts, 0, a0, a1)
        self._open[track].append(name)
        return ts

    def end(self, track: int, name: int, a0: int = 0, a1: int = 0) -> None:
        """Close the innermost open span on ``track``.  A no-op if the
        span was already force-closed by :meth:`reset` (so callers never
        need to remember whether a reset happened mid-span)."""
        stack = self._open[track]
        if not stack or stack[-1] != name:
            return
        stack.pop()
        self._record(KIND_E, track, name, perf_counter_ns(), 0, a0, a1)

    def instant(self, track: int, name: int, a0: int = 0, a1: int = 0) -> None:
        self._record(KIND_I, track, name, perf_counter_ns(), 0, a0, a1)

    def complete(
        self, track: int, name: int, ts_ns: int, dur_ns: int,
        a0: int = 0, a1: int = 0,
    ) -> None:
        """A span with explicit start/duration (Chrome ``X`` phase) —
        for intervals whose start predates the recording call, e.g.
        queue wait stamped once at admission."""
        self._record(KIND_X, track, name, ts_ns, max(0, dur_ns), a0, a1)

    def counter(self, track: int, name: int, value: int) -> None:
        """One counter sample (Chrome ``C`` phase): the series ``name``
        on ``track`` takes integer ``value`` as of now.  Counters never
        touch the open-span stacks."""
        self._record(KIND_C, track, name, perf_counter_ns(), 0, int(value), 0)

    # -- lifecycle -----------------------------------------------------

    def reset(self) -> None:
        """Clear the ring.  Open spans are closed (not leaked): each is
        counted in ``truncated_spans`` and its future ``end()`` becomes
        a no-op.  Interned track/name ids survive — engine code holds
        resolved ids."""
        for stack in self._open:
            self.truncated_spans += len(stack)
            stack.clear()
        self._n = 0

    @property
    def n_events(self) -> int:
        """Events currently held in the ring."""
        return min(self._n, self._cap)

    @property
    def n_recorded(self) -> int:
        """Events ever recorded (>= n_events once the ring wraps)."""
        return self._n

    def open_spans(self) -> dict[str, list[str]]:
        """Track label -> open span names, outermost first (debugging)."""
        return {
            self._track_labels[t]: [self._name_labels[n] for n in stack]
            for t, stack in enumerate(self._open)
            if stack
        }

    # -- export --------------------------------------------------------

    def events(self) -> list[dict]:
        """The retained ring contents, oldest first, as plain dicts with
        interned labels resolved.  The Perfetto exporter consumes this;
        tests can too."""
        start = max(0, self._n - self._cap)
        out = []
        for j in range(start, self._n):
            i = j & self._mask
            out.append(
                {
                    "kind": int(self._kind[i]),
                    "track": self._track_labels[int(self._track[i])],
                    "name": self._name_labels[int(self._name[i])],
                    "ts_ns": int(self._ts[i]),
                    "dur_ns": int(self._dur[i]),
                    "a0": int(self._a0[i]),
                    "a1": int(self._a1[i]),
                }
            )
        return out

    def export_perfetto(self, path: str, pid: int = 0) -> int:
        """Write a Chrome trace-event JSON file (openable in
        ui.perfetto.dev).  Returns the number of events written."""
        from .perfetto import export_perfetto

        return export_perfetto({pid: self}, path)


class NullTracer:
    """No-op tracer bound to disabled engines.  Same surface as
    :class:`Tracer`; every method returns immediately so hot-path call
    sites stay branch-free and cost one attribute lookup + call."""

    enabled = False
    truncated_spans = 0
    n_events = 0
    n_recorded = 0
    _track_labels: tuple = ()  # exporters see an empty process

    def track(self, label: str) -> int:
        return 0

    def name(self, label: str) -> int:
        return 0

    def begin(self, track: int, name: int, a0: int = 0, a1: int = 0) -> int:
        return 0

    def end(self, track: int, name: int, a0: int = 0, a1: int = 0) -> None:
        return None

    def instant(self, track: int, name: int, a0: int = 0, a1: int = 0) -> None:
        return None

    def complete(
        self, track: int, name: int, ts_ns: int, dur_ns: int,
        a0: int = 0, a1: int = 0,
    ) -> None:
        return None

    def counter(self, track: int, name: int, value: int) -> None:
        return None

    def reset(self) -> None:
        return None

    def open_spans(self) -> dict:
        return {}

    def events(self) -> list:
        return []

    def export_perfetto(self, path: str, pid: int = 0) -> int:
        raise RuntimeError(
            "tracing is disabled (EngineConfig(trace=False)); nothing to "
            "export"
        )


NULL_TRACER = NullTracer()
