"""Multi-window SLO burn-rate monitoring (Google-SRE style).

The serving stats already count the ground truth: every deadline'd
request increments ``repro_serve_slo_requests_total`` and, on success,
``repro_serve_slo_met_total`` (a rejected deadline'd request counts as
a miss); structured rejections land in ``repro_serve_rejected_total``
by reason.  The monitor reads those through a :class:`WindowedView` and
computes the classic *burn rate*: the window's error fraction divided
by the error budget ``1 - target``.  Burn 1.0 means "missing exactly as
fast as the SLO allows"; burn 10 on a 99% target means 10% of requests
are missing their deadlines.

Multi-window rule: an alert state requires the burn to exceed its
threshold over **both** the fast and the slow window — the fast window
gives low detection latency, the slow window keeps a two-second blip
from paging (the AND of the two is the standard SRE construction).  The
timescales are configuration (``SloConfig``): production-ish defaults
here, scaled down to sub-second windows by the ``--smoke`` benchmark.

Error events are deadline misses.  When a window holds *no* deadline'd
traffic, the monitor falls back to the rejection fraction over all
terminal outcomes (finished + rejected), so a rejection storm on a
deadline-free deployment still burns.  ``shed`` rejections are excluded
from the error count either way: shedding is the monitor's own
*response* to a burn, and counting it as error would latch CRITICAL
forever.

The optional load-shed feedback (``SloConfig(shed=True)``) is wired by
the engine: while the state is CRITICAL it rejects up to
``shed_max_per_tick`` lowest-priority queued requests per step
(structured ``REJECT_SHED`` results, never silent drops).  Off by
default — monitoring alone must never change a token stream.
"""

from __future__ import annotations

import dataclasses

from .windows import WindowedView

__all__ = ["SloConfig", "BurnRateMonitor", "OK", "WARN", "CRITICAL"]

OK = "OK"
WARN = "WARN"
CRITICAL = "CRITICAL"
_STATE_CODE = {OK: 0, WARN: 1, CRITICAL: 2}

# metric names the monitor reads (defined by repro.serving.stats)
_SLO_TOTAL = "repro_serve_slo_requests_total"
_SLO_MET = "repro_serve_slo_met_total"
_FINISHED = "repro_serve_requests_finished_total"
_REJECTED = "repro_serve_rejected_total"
_SHED_REASON = "shed"


@dataclasses.dataclass(frozen=True)
class SloConfig:
    """Burn-rate monitor knobs.

    ``target`` is the SLO attainment objective (0.99 = 99% of
    deadline'd requests meet their deadline).  ``warn_burn`` /
    ``critical_burn`` are burn-rate thresholds that must hold over both
    windows.  ``shed`` arms the CRITICAL feedback: the engine sheds up
    to ``shed_max_per_tick`` lowest-priority queued requests per step
    while CRITICAL (graceful degradation; off by default)."""

    target: float = 0.99
    fast_window_s: float = 60.0
    slow_window_s: float = 300.0
    warn_burn: float = 2.0
    critical_burn: float = 6.0
    shed: bool = False
    shed_max_per_tick: int = 2

    def __post_init__(self):
        if not 0.0 < self.target < 1.0:
            raise ValueError("target must be in (0, 1)")
        if self.fast_window_s <= 0 or self.slow_window_s <= 0:
            raise ValueError("windows must be > 0 seconds")
        if self.fast_window_s > self.slow_window_s:
            raise ValueError("fast_window_s must be <= slow_window_s")
        if self.warn_burn <= 0 or self.critical_burn < self.warn_burn:
            raise ValueError(
                "need 0 < warn_burn <= critical_burn"
            )
        if self.shed_max_per_tick < 1:
            raise ValueError("shed_max_per_tick must be >= 1")


class BurnRateMonitor:
    """Evaluates burn over a shared :class:`WindowedView` (whose
    ``window_s`` must cover ``slow_window_s`` — the engine sizes it).

    ``evaluate()`` recomputes the state and returns the full status
    dict; ``last`` keeps the most recent result so read-only consumers
    (the ``/slo`` endpoint, running on the HTTP thread) never race the
    engine's evaluation."""

    def __init__(self, window: WindowedView, cfg: SloConfig):
        if window.window_s + 1e-9 < cfg.slow_window_s:
            raise ValueError(
                f"window retention {window.window_s}s shorter than the "
                f"slow SLO window {cfg.slow_window_s}s"
            )
        self.window = window
        self.cfg = cfg
        self.state = OK
        self.transitions: dict[str, int] = {WARN: 0, CRITICAL: 0}
        self.last: dict = self._status(0.0, 0.0, {}, {})

    # ---- burn math ---------------------------------------------------
    def _window_errors(self, span_s: float) -> dict:
        w = self.window
        total = w.delta(_SLO_TOTAL, span_s)
        met = w.delta(_SLO_MET, span_s)
        rejected = w.delta(_REJECTED, span_s)
        shed = w.delta(_REJECTED, span_s, label=_SHED_REASON)
        if total > 0:
            errors, base = total - met, total
        else:
            # no deadline'd traffic in the window: burn over the
            # non-shed rejection fraction of terminal outcomes
            errors = rejected - shed
            base = w.delta(_FINISHED, span_s) + errors
        rate = errors / base if base > 0 else 0.0
        return {
            "errors": errors,
            "base": base,
            "error_rate": rate,
            "burn": rate / (1.0 - self.cfg.target),
        }

    def _status(self, fast_burn, slow_burn, fast, slow) -> dict:
        return {
            "state": self.state,
            "state_code": _STATE_CODE[self.state],
            "target": self.cfg.target,
            "fast_window_s": self.cfg.fast_window_s,
            "slow_window_s": self.cfg.slow_window_s,
            "fast_burn": round(float(fast_burn), 4),
            "slow_burn": round(float(slow_burn), 4),
            "warn_burn": self.cfg.warn_burn,
            "critical_burn": self.cfg.critical_burn,
            "shed_enabled": self.cfg.shed,
            "windows": {"fast": fast, "slow": slow},
            "transitions": dict(self.transitions),
        }

    def evaluate(self) -> dict:
        """Recompute burn over both windows; returns (and retains as
        ``last``) the status dict.  ``transitioned_to`` is the state
        just entered, or None — the engine's shed/flight hooks fire on
        transitions, not on every CRITICAL tick."""
        fast = self._window_errors(self.cfg.fast_window_s)
        slow = self._window_errors(self.cfg.slow_window_s)
        burn = min(fast["burn"], slow["burn"])  # multi-window AND
        if burn >= self.cfg.critical_burn:
            new = CRITICAL
        elif burn >= self.cfg.warn_burn:
            new = WARN
        else:
            new = OK
        transitioned = new if new != self.state else None
        if transitioned in self.transitions:
            self.transitions[transitioned] += 1
        self.state = new
        out = self._status(fast["burn"], slow["burn"], fast, slow)
        out["transitioned_to"] = transitioned
        self.last = out
        return out
