"""Prometheus text-exposition rendering for a MetricsRegistry.

Render-on-demand snapshot (no HTTP server — `launch/serve.py` writes
the snapshot to ``--metrics-out`` after draining, and a real deployment
would serve :func:`render` from its scrape endpoint).  Output follows
the text exposition format version 0.0.4: ``# HELP`` / ``# TYPE``
headers, counters suffixed ``_total`` by naming convention, histograms
as cumulative ``_bucket{le=...}`` series plus ``_sum`` / ``_count``.

:func:`parse` is the inverse for sample lines only — enough for tests
and the tier-1 round-trip to assert the exposition agrees with
``stats_summary()`` on shared counters.
"""

from __future__ import annotations

import math
import re
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from .metrics import MetricsRegistry

__all__ = ["render", "write_snapshot", "parse"]

_LABEL_SANITIZE = re.compile(r"([\\\"\n])")


def _fmt_value(v: int | float) -> str:
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    if math.isnan(v):
        return "NaN"  # canonical exposition spelling (repr gives 'nan')
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def _fmt_label(labelname: str, key: object) -> str:
    if isinstance(key, tuple):  # e.g. prefill bucket (N, S) -> "2x64"
        val = "x".join(str(k) for k in key)
    else:
        val = str(key)
    val = _LABEL_SANITIZE.sub(r"\\\1", val).replace("\n", "\\n")
    return f'{labelname}="{val}"'


def render(registry: "MetricsRegistry") -> str:
    from .metrics import Counter, Gauge, Histogram

    lines: list[str] = []
    for m in registry.collect():
        if isinstance(m, Counter):
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} counter")
            if m.labelname:
                for key, v in sorted(m.items(), key=lambda kv: str(kv[0])):
                    lines.append(
                        f"{m.name}{{{_fmt_label(m.labelname, key)}}} "
                        f"{_fmt_value(v)}"
                    )
                if not m.items():
                    # expose the zero series so the metric is scrapeable
                    lines.append(f"{m.name} 0")
            else:
                lines.append(f"{m.name} {_fmt_value(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} gauge")
            lines.append(f"{m.name} {_fmt_value(m.value)}")
        elif isinstance(m, Histogram):
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} histogram")
            for bound, cum in m.cumulative_buckets():
                lines.append(
                    f'{m.name}_bucket{{le="{_fmt_value(bound)}"}} {cum}'
                )
            lines.append(f"{m.name}_sum {_fmt_value(m.sum)}")
            lines.append(f"{m.name}_count {m.count}")
    return "\n".join(lines) + "\n"


def write_snapshot(path: str, registry: "MetricsRegistry") -> None:
    with open(path, "w") as f:
        f.write(render(registry))


_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)\s*$"
)


def parse(text: str) -> dict[str, float]:
    """Sample lines -> {'name' or 'name{labels}': value}.  Raises
    ValueError on a malformed sample line (comment lines are skipped),
    so the tier-1 round-trip actually validates the exposition."""
    out: dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if m is None:
            raise ValueError(f"prom parse: bad sample line {lineno}: {line!r}")
        raw = m.group("value")
        if raw == "+Inf":
            val = math.inf
        elif raw == "-Inf":
            val = -math.inf
        else:
            val = float(raw)
        key = m.group("name")
        if m.group("labels"):
            key += "{" + m.group("labels") + "}"
        out[key] = val
    return out
