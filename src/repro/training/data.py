"""Data pipeline: deterministic, resumable token streams.

Two sources:
- ``SyntheticLM``: a seeded Markov-ish token generator — cheap, infinite,
  and *step-addressable* (batch(step) is a pure function of (seed, step)),
  which makes checkpoint-resume trivially exact and lets any host compute
  its own shard without coordination (the property a 1000-node input
  pipeline needs).
- ``TextFileLM``: byte-level tokenization of a local file with the same
  step-addressable contract.

Batches are {"tokens", "labels"} with labels = next-token shift. For
stub-frontend archs (vlm/audio), ``EmbedsWrapper`` converts tokens to
deterministic pseudo-embeddings of the right width.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM", "TextFileLM", "EmbedsWrapper"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        b, s, v = self.batch_size, self.seq_len, self.vocab_size
        # structured stream: a noisy cyclic pattern so a real model can
        # actually reduce loss (used by convergence/integration tests)
        base = rng.integers(0, v, size=(b, 1))
        steps = rng.integers(1, 7, size=(b, 1))
        seq = (base + steps * np.arange(s + 1)[None, :]) % v
        noise = rng.random((b, s + 1)) < 0.05
        seq = np.where(noise, rng.integers(0, v, size=(b, s + 1)), seq)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def state(self) -> dict:
        return {"seed": self.seed}


@dataclasses.dataclass
class TextFileLM:
    path: str
    seq_len: int
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        with open(self.path, "rb") as f:
            self._data = np.frombuffer(f.read(), dtype=np.uint8)
        if len(self._data) < self.seq_len + 2:
            raise ValueError("file too small for seq_len")

    @property
    def vocab_size(self) -> int:
        return 256

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        n = len(self._data) - self.seq_len - 1
        starts = rng.integers(0, n, size=self.batch_size)
        toks = np.stack(
            [self._data[s : s + self.seq_len + 1] for s in starts]
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self) -> dict:
        return {"seed": self.seed, "path": self.path}


@dataclasses.dataclass
class EmbedsWrapper:
    """Stub modality frontend: maps token batches to deterministic
    pseudo-embeddings (B, S, d_model) — the [vlm]/[audio] contract."""

    inner: object
    d_model: int
    n_pos_streams: int = 0  # 3 for M-RoPE

    def batch(self, step: int) -> dict:
        b = self.inner.batch(step)
        toks = b["tokens"]
        bsz, s = toks.shape
        rng = np.random.default_rng(0)
        table = rng.standard_normal((self.inner.vocab_size, self.d_model)).astype(
            np.float32
        ) * 0.02
        out = {"embeds": table[toks], "labels": b["labels"]}
        if self.n_pos_streams:
            pos = np.broadcast_to(
                np.arange(s, dtype=np.int32)[None, :, None],
                (bsz, s, self.n_pos_streams),
            )
            out["positions"] = np.ascontiguousarray(pos)
        return out

    def state(self) -> dict:
        return self.inner.state()
