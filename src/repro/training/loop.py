"""Training loop with the fault-tolerance features a 1000-node run needs:

- checkpoint/restart (atomic, integrity-checked, elastic across meshes)
- preemption handling (SIGTERM/SIGINT -> checkpoint -> clean exit)
- straggler detection (step-time EWMA watchdog; on a real cluster the
  callback would trigger hot-spare promotion / re-slicing — here it logs
  and counts, and the hook is injectable for tests)
- deterministic resume of the data stream (step-addressable batches)
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import transformer as T
from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import OptConfig, apply_updates, init_opt_state

__all__ = ["TrainConfig", "make_train_step", "Trainer"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3
    straggler_factor: float = 3.0
    seed: int = 0


def make_train_step(
    model_cfg: ModelConfig,
    opt_cfg: OptConfig,
    *,
    impl: str | None = None,
    microbatches: int = 1,
) -> Callable:
    """Pure (state, batch) -> (state, metrics).

    ``microbatches > 1`` enables gradient accumulation: the global batch is
    split into k sequential microbatches (a lax.scan), bounding live
    activations to one microbatch — how a 67B model trains at
    global_batch 256 x 4096 without 100+ GB of residual-carry per device.
    """

    def loss_fn(params, mb):
        return T.forward_train(model_cfg, params, mb, impl=impl)

    def train_step(state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(state["params"], batch)
        else:
            k = microbatches
            ba = model_cfg.batch_axes or None

            def split(a):
                a = a.reshape(k, a.shape[0] // k, *a.shape[1:])
                return a

            batch_r = jax.tree.map(split, batch)

            def micro(carry, mb):
                gsum, lsum, msum = carry
                if ba is not None:
                    from jax.sharding import PartitionSpec as P

                    mb = jax.tree.map(
                        lambda a: jax.lax.with_sharding_constraint(
                            a, P(ba, *([None] * (a.ndim - 1)))
                        )
                        if a.ndim >= 1
                        else a,
                        mb,
                    )
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(state["params"], mb)
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(s.dtype), gsum, g
                )
                msum = jax.tree.map(lambda s, x: s + x, msum, metrics)
                return (gsum, lsum + loss, msum), None

            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            mz = {"nll": jnp.zeros(()), "lb_loss": jnp.zeros(())}
            (gsum, lsum, msum), _ = jax.lax.scan(
                micro, (gz, jnp.zeros(()), mz), batch_r
            )
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
            metrics = jax.tree.map(lambda m: m / k, msum)
        new_params, new_opt, opt_m = apply_updates(
            opt_cfg, state["params"], grads, state["opt"]
        )
        out = {"loss": loss, **metrics, **opt_m}
        return {"params": new_params, "opt": new_opt}, out

    return train_step


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        opt_cfg: OptConfig,
        data,
        mesh,
        train_cfg: TrainConfig = TrainConfig(),
        *,
        strategy: str = "fsdp",
        impl: str | None = None,
        straggler_hook: Callable[[int, float, float], None] | None = None,
    ):
        self.model_cfg, self.opt_cfg = model_cfg, opt_cfg
        self.data, self.mesh, self.cfg = data, mesh, train_cfg
        self.step = 0
        self._preempted = False
        self._straggler_hook = straggler_hook
        self.straggler_events = 0
        self._ewma: float | None = None

        st = sharding.Strategy(mesh, strategy)
        self.strategy = st
        self.model_cfg = model_cfg = model_cfg.replace(
            tp_size=st.tp_size, batch_axes=st.batch
        )
        with mesh:
            key = jax.random.PRNGKey(train_cfg.seed)
            params_shape = jax.eval_shape(
                lambda k: T.init_model(k, model_cfg), key
            )
            self.param_shardings = sharding.param_shardings(st, params_shape)
            opt_shape = jax.eval_shape(
                lambda p: init_opt_state(opt_cfg, p), params_shape
            )
            self.state_shardings = {
                "params": self.param_shardings,
                "opt": {
                    "step": sharding.named(
                        mesh, jax.tree.map(lambda _: jax.sharding.PartitionSpec(), opt_shape["step"])
                    ),
                    "mu": sharding.param_shardings(st, opt_shape["mu"]),
                    "nu": sharding.param_shardings(st, opt_shape["nu"]),
                    **(
                        {"ef": sharding.param_shardings(st, opt_shape["ef"])}
                        if "ef" in opt_shape
                        else {}
                    ),
                },
            }
            example = self.data.batch(0)
            self.batch_shardings = sharding.named(
                st, sharding.batch_specs(st, example)
            )
            self._step_fn = jax.jit(
                make_train_step(model_cfg, opt_cfg, impl=impl),
                in_shardings=(self.state_shardings, self.batch_shardings),
                out_shardings=(self.state_shardings, None),
                donate_argnums=(0,),
            )

        # try restore, else init
        last = ckpt_lib.latest_step(train_cfg.ckpt_dir)
        template = {
            "params": params_shape,
            "opt": opt_shape,
        }
        if last is not None:
            with mesh:
                state, extra = ckpt_lib.restore(
                    train_cfg.ckpt_dir,
                    template,
                    shardings=self.state_shardings,
                )
            self.state = state
            self.step = int(extra.get("step", last))
            print(f"[trainer] restored step {self.step} from {train_cfg.ckpt_dir}")
        else:
            with mesh:
                init = jax.jit(
                    lambda k: {
                        "params": (p := T.init_model(k, model_cfg)),
                        "opt": init_opt_state(opt_cfg, p),
                    },
                    out_shardings=self.state_shardings,
                )
                self.state = init(key)

        signal.signal(signal.SIGTERM, self._on_preempt)

    # ------------------------------------------------------------------
    def _on_preempt(self, signum, frame):
        self._preempted = True

    def checkpoint(self):
        ckpt_lib.save(
            self.cfg.ckpt_dir,
            self.step,
            self.state,
            extra={"step": self.step, "data": self.data.state()},
            keep=self.cfg.keep,
        )

    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.cfg.steps
        history = []
        with self.mesh:
            for _ in range(steps):
                if self._preempted:
                    print("[trainer] preemption signal: checkpoint + exit")
                    self.checkpoint()
                    break
                batch = jax.device_put(
                    self.data.batch(self.step), self.batch_shardings
                )
                t0 = time.perf_counter()
                self.state, metrics = self._step_fn(self.state, batch)
                metrics = jax.tree.map(float, jax.device_get(metrics))
                dt = time.perf_counter() - t0
                self._watch(dt)
                self.step += 1
                metrics["step"] = self.step
                metrics["step_time_s"] = dt
                history.append(metrics)
                if self.step % self.cfg.log_every == 0:
                    print(
                        f"[trainer] step {self.step} loss={metrics['loss']:.4f} "
                        f"lr={metrics['lr']:.2e} {dt*1e3:.0f}ms"
                    )
                if self.step % self.cfg.ckpt_every == 0:
                    self.checkpoint()
        return history

    def _watch(self, dt: float):
        """Straggler watchdog: EWMA of step time; flag outliers."""
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma:
            self.straggler_events += 1
            if self._straggler_hook:
                self._straggler_hook(self.step, dt, self._ewma)
            else:
                print(
                    f"[trainer] straggler: step took {dt:.3f}s vs "
                    f"EWMA {self._ewma:.3f}s"
                )
        self._ewma = 0.9 * self._ewma + 0.1 * dt
