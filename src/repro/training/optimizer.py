"""Optimizer: AdamW + warmup-cosine schedule + global-norm clipping,
with an optional error-feedback int8 gradient-compression hook.

Self-contained (no optax in this container). State is a plain pytree so it
shards with the same FSDP rules as parameters and checkpoints trivially.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # beyond-paper: error-feedback int8 gradient compression (models the
    # numerics of compressed all-reduce; see DESIGN.md §4)
    compress_grads: bool = False


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * (step + 1.0) / max(1, cfg.warmup_steps)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(cfg: OptConfig, params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
    }
    if cfg.compress_grads:
        state["ef"] = jax.tree.map(zeros32, params)  # error-feedback residual
    return state


def _compress_int8(g: jax.Array, residual: jax.Array):
    """Symmetric per-tensor int8 quantization with error feedback."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    deq = q * scale
    return deq, gf - deq


def _global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: OptConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.compress_grads:
        pairs = jax.tree.map(_compress_int8, grads, state["ef"])
        grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_mu = jax.tree.map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_nu = jax.tree.map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_state = {"step": step, "mu": new_mu, "nu": new_nu}
    if cfg.compress_grads:
        new_state["ef"] = new_ef
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
