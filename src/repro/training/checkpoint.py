"""Fault-tolerant checkpointing.

Properties required at 1000-node scale and implemented here:

- **atomic**: written to ``step_XXXX.tmp`` then ``os.rename``d, so a
  preemption mid-write never corrupts the latest checkpoint.
- **integrity-checked**: a manifest (JSON) records per-array shape/dtype
  and a CRC32; restore verifies before handing arrays to the trainer.
- **layout-agnostic (elastic)**: arrays are saved *unsharded by logical
  name*, not by device layout, so a run can restart on a different mesh
  (e.g. after losing a pod) — the trainer re-applies its own shardings via
  ``jax.device_put``.
- **resumable data**: the data-iterator state (seed, step) and RNG key are
  part of the checkpoint, so restart is bitwise-continuable.
- retention: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointError"]


class CheckpointError(RuntimeError):
    pass


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    extra: dict | None = None,
    keep: int = 3,
) -> str:
    """Atomically write checkpoint for ``step``. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "arrays": {}}
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **flat)
    for k, v in flat.items():
        manifest["arrays"][k] = {
            "shape": list(v.shape),
            "dtype": str(v.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):  # stale partial writes
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    template: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``template``; verify CRCs; optionally
    re-place onto ``shardings`` (elastic restart on a new mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise CheckpointError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if key not in manifest["arrays"]:
            raise CheckpointError(f"missing array {key!r} in checkpoint")
        meta = manifest["arrays"][key]
        arr = data[key]
        if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
            raise CheckpointError(f"metadata mismatch for {key!r}")
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
            raise CheckpointError(f"CRC mismatch for {key!r} (corrupt file)")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise CheckpointError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, manifest["extra"]
