"""Render the §Dry-run / §Roofline markdown tables from the sweep JSON."""

from __future__ import annotations

import json
import sys


def fmt_bytes(x) -> str:
    if x is None:
        return "-"
    return f"{x/1e9:.1f}"


def render(path: str) -> str:
    rows = json.load(open(path))
    ok = [r for r in rows if r.get("ok")]
    bad = [r for r in rows if not r.get("ok")]
    out = []
    out.append(
        "| arch | shape | mesh | strat | compile s | temp GB/dev | compute ms "
        "| memory ms | collective ms | bound | useful |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('strategy','?')} "
            f"| {r['compile_s']} | {fmt_bytes(r['bytes_per_device'])} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.0f} "
            f"| {r['collective_s']*1e3:.0f} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} |"
        )
    for r in bad:
        out.append(
            f"| {r['arch']} | {r['shape']} | {'2x16x16' if r.get('multi_pod') else '16x16'} "
            f"| - | FAILED | - | - | - | - | - | {r.get('error','')[:40]} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else "dryrun_baseline.json"))
