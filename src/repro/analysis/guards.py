"""Runtime dispatch guards for the serving hot path.

Static analysis (`repro.analysis.jaxlint`) proves hot-path invariants on
the source tree; this module proves them at execution time.  The two
invariants that matter for steady-state decode throughput:

  1. **Zero recompiles per decode step after warmup.**  Every jit
     program variant the engine can dispatch is traced at init
     (`Engine.__init__` warms both the plain-argmax and fused-sampler
     variants per bucket); a compile appearing mid-traffic means a shape
     or static-arg leaked into the dispatch path (the PR 3 regression).
  2. **Zero *implicit* device→host transfers per decode step.**  The one
     sanctioned sync per step is the explicit batched `jax.device_get`
     of the next-token row; anything else (`.item()`, `int()`/`bool()`
     on a device array, `np.asarray`, implicit `__bool__`) serializes
     the device stream per call (the PR 6 regression).

`DispatchGuard` enforces both as a context manager:

  * Compiles are counted via a `jax.monitoring` duration listener on the
    backend-compile event — cache hits do not fire it, real compiles do.
  * Implicit syncs are intercepted by patching the host-conversion entry
    points on jax's `ArrayImpl` (``__array__``, ``item``, ``__bool__``,
    ...) for the duration of the context.  This works on every backend,
    including CPU — where `jax.transfer_guard_device_to_host` is inert
    because arrays are already host-resident.  On accelerator backends
    the real transfer guard is additionally armed, so DMA-level implicit
    transfers that bypass ArrayImpl methods are caught too.
  * `jax.device_get` stays the sanctioned explicit channel: the guard
    wraps it to flag the conversion as intentional (and counts calls),
    so batched fetches pass while stray scalar pulls raise.

Known hole, by construction: on CPU, `np.asarray(x)` converts through
the C-level buffer protocol (zero-copy into host-resident memory — no
transfer exists to catch) and never reaches ``__array__``, so the
runtime guard cannot see it there.  jaxlint's JL001 flags it statically
instead, and on accelerator backends (no buffer protocol) the
``__array__`` patch plus the real transfer guard do catch it.

Not thread-safe: the ArrayImpl patch is process-global while the
context is active.  The engine is single-threaded; tests and benchmarks
use one guard at a time.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable

import jax

__all__ = [
    "hot_path",
    "is_hot_path",
    "HostSyncError",
    "RecompileError",
    "DispatchGuard",
    "compile_events_total",
]


def hot_path(fn: Callable) -> Callable:
    """Marker decorator: ``fn`` is on the serving hot path.

    Purely declarative — returns ``fn`` unchanged with a ``__hot_path__``
    attribute.  `repro.analysis.jaxlint` keys its JL001 rule (no implicit
    host syncs) on this marker, and reviewers can grep for it to find
    every function where a stray `.item()` is a throughput bug rather
    than a style nit.
    """
    fn.__hot_path__ = True
    return fn


def is_hot_path(fn: Callable) -> bool:
    return bool(getattr(fn, "__hot_path__", False))


class HostSyncError(RuntimeError):
    """An implicit device→host sync fired inside a DispatchGuard."""


class RecompileError(RuntimeError):
    """A compile fired inside a DispatchGuard that forbids compiles."""


# ---------------------------------------------------------------------------
# Compile counting.
#
# jax.monitoring has no listener-unregister API (only a global clear), so
# we register exactly one process-lifetime listener that bumps a counter
# whenever the backend compiles a program.  Guards snapshot the counter
# at enter/exit.  The event name has been
# "/jax/core/compile/backend_compile_duration" across recent jax
# releases; substring-match to stay tolerant of path shuffles.
# ---------------------------------------------------------------------------

_compile_lock = threading.Lock()
_compile_events = 0
_listener_registered = False


def _on_event_duration(event: str, duration: float, **_kw: Any) -> None:
    global _compile_events
    if "backend_compile" in event:
        with _compile_lock:
            _compile_events += 1


def _ensure_listener() -> None:
    global _listener_registered
    if not _listener_registered:
        jax.monitoring.register_event_duration_secs_listener(_on_event_duration)
        _listener_registered = True


def compile_events_total() -> int:
    """Process-lifetime count of backend compiles observed so far."""
    _ensure_listener()
    with _compile_lock:
        return _compile_events


# ---------------------------------------------------------------------------
# Implicit-sync interception.
#
# jax assigns plain Python functions onto the ArrayImpl C type for its
# host-conversion surface (e.g. ``ArrayImpl.item = jax._src.array._item``),
# so those entry points are patchable per-context.  Special methods are
# looked up on the type, so ``int(x)`` / ``if x:`` / ``np.asarray(x)``
# all route through the patched functions.
# ---------------------------------------------------------------------------

_SYNC_METHODS = (
    "__array__",
    "__bool__",
    "__int__",
    "__float__",
    "__index__",
    "__complex__",
    "item",
    "tolist",
)


_array_impl_cls: type | None = None


def _array_impl_type() -> type:
    # The concrete on-device array type; committed arrays and jit outputs
    # are instances.  Resolve lazily so import order doesn't matter, and
    # cache it: building the probe array compiles a tiny program the
    # first time, which must not be charged to a guarded region.
    global _array_impl_cls
    if _array_impl_cls is None:
        _array_impl_cls = type(jax.numpy.zeros(()))
    return _array_impl_cls


@dataclasses.dataclass
class GuardReport:
    steps: int = 0
    compiles: int = 0
    implicit_syncs: int = 0
    explicit_syncs: int = 0


class DispatchGuard:
    """Context manager asserting steady-state dispatch hygiene.

    Inside the context:
      * implicit host syncs on jax arrays raise :class:`HostSyncError`
        immediately (naming the entry point), unless ``raise_on_sync``
        is False, in which case they are only counted;
      * `jax.device_get` is allowed and counted as an explicit sync;
      * backend compiles are counted; if ``max_compiles`` is not None
        and the count exceeds it, ``__exit__`` raises
        :class:`RecompileError`.

    Typical use around a steady-state decode loop::

        with DispatchGuard(max_compiles=0) as g:
            while engine.scheduler.active():
                engine.step()
        assert g.compiles == 0 and g.implicit_syncs == 0
    """

    def __init__(
        self,
        *,
        max_compiles: int | None = 0,
        raise_on_sync: bool = True,
        transfer_guard: bool = True,
        metrics=None,
    ) -> None:
        """``metrics``: optional ``repro.obs.MetricsRegistry``.  On exit
        the guarded region's counts land in ``repro_guard_compiles_total``
        / ``_implicit_syncs_total`` / ``_explicit_syncs_total``, so
        guarded benchmark loops show up in the same Prometheus snapshot
        as the engine's own counters."""
        self.max_compiles = max_compiles
        self.raise_on_sync = raise_on_sync
        self.transfer_guard = transfer_guard
        self.metrics = metrics
        self.implicit_syncs = 0
        self.explicit_syncs = 0
        self._compiles_at_enter = 0
        self._compiles_at_exit: int | None = None
        self._saved: dict[str, Any] = {}
        self._saved_device_get: Callable | None = None
        self._exit_stack: contextlib.ExitStack | None = None
        self._in_explicit = False
        self._active = False

    # -- counters ----------------------------------------------------------

    @property
    def compiles(self) -> int:
        end = (
            self._compiles_at_exit
            if self._compiles_at_exit is not None
            else compile_events_total()
        )
        return end - self._compiles_at_enter

    def report(self, steps: int = 0) -> GuardReport:
        return GuardReport(
            steps=steps,
            compiles=self.compiles,
            implicit_syncs=self.implicit_syncs,
            explicit_syncs=self.explicit_syncs,
        )

    # -- interception ------------------------------------------------------

    def _trip(self, name: str) -> None:
        if self._in_explicit:
            return  # inside the sanctioned jax.device_get path
        self.implicit_syncs += 1
        if self.raise_on_sync:
            raise HostSyncError(
                f"implicit device->host sync via ArrayImpl.{name} inside a "
                "DispatchGuard. Hot-path code must batch host reads through "
                "one explicit jax.device_get per step (jaxlint JL001)."
            )

    def _make_patch(self, name: str, orig: Callable) -> Callable:
        guard = self

        def patched(array_self, *args: Any, **kwargs: Any):
            guard._trip(name)
            return orig(array_self, *args, **kwargs)

        patched.__name__ = name
        return patched

    def __enter__(self) -> "DispatchGuard":
        if self._active:
            raise RuntimeError("DispatchGuard is not reentrant")
        _ensure_listener()
        self._active = True
        self._compiles_at_exit = None
        self.implicit_syncs = 0
        self.explicit_syncs = 0

        cls = _array_impl_type()
        self._saved = {}
        for name in _SYNC_METHODS:
            orig = getattr(cls, name, None)
            if orig is None:
                continue
            self._saved[name] = orig
            setattr(cls, name, self._make_patch(name, orig))

        # Sanctioned explicit channel: route jax.device_get through a
        # wrapper that suspends interception (device_get internally calls
        # np.asarray -> __array__ on each leaf).
        orig_get = jax.device_get
        self._saved_device_get = orig_get
        guard = self

        def guarded_device_get(tree):
            guard.explicit_syncs += 1
            guard._in_explicit = True
            try:
                return orig_get(tree)
            finally:
                guard._in_explicit = False

        jax.device_get = guarded_device_get

        # On accelerator backends additionally arm the real transfer
        # guard (catches DMA-level implicit transfers that never route
        # through ArrayImpl methods).  Inert on CPU, where arrays are
        # already host-resident.
        self._exit_stack = contextlib.ExitStack()
        if self.transfer_guard:
            self._exit_stack.enter_context(
                jax.transfer_guard_device_to_host("disallow")
            )
        # Snapshot last: nothing the guard's own setup does (type
        # resolution, patching, arming the transfer guard) may count
        # against the guarded region.
        self._compiles_at_enter = compile_events_total()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        cls = _array_impl_type()
        for name, orig in self._saved.items():
            setattr(cls, name, orig)
        self._saved = {}
        if self._saved_device_get is not None:
            jax.device_get = self._saved_device_get
            self._saved_device_get = None
        if self._exit_stack is not None:
            self._exit_stack.close()
            self._exit_stack = None
        self._compiles_at_exit = compile_events_total()
        self._active = False
        if self.metrics is not None:
            self.metrics.counter(
                "repro_guard_compiles_total",
                "Backend compiles inside DispatchGuard regions",
            ).inc(self.compiles)
            self.metrics.counter(
                "repro_guard_implicit_syncs_total",
                "Implicit device->host syncs inside DispatchGuard regions",
            ).inc(self.implicit_syncs)
            self.metrics.counter(
                "repro_guard_explicit_syncs_total",
                "Sanctioned jax.device_get calls inside DispatchGuard "
                "regions",
            ).inc(self.explicit_syncs)
        if exc_type is not None:
            return False
        if self.max_compiles is not None and self.compiles > self.max_compiles:
            raise RecompileError(
                f"{self.compiles} backend compile(s) fired inside a "
                f"DispatchGuard (max_compiles={self.max_compiles}). A compile "
                "after warmup means a shape or static argument leaked into "
                "the dispatch path (jaxlint JL003)."
            )
        return False
