"""Roofline analysis from compiled (post-SPMD, per-device) HLO text.

Why not ``compiled.cost_analysis()``: XLA's cost analysis counts a while
loop's body ONCE, ignoring the trip count — with scan-over-layers a 95-layer
model reports 1 layer of FLOPs. This walker parses the optimized HLO,
multiplies every computation by its enclosing loops' ``known_trip_count``,
and accounts:

  - dot FLOPs (2 * prod(out_shape) * prod(contracting_sizes)),
  - convolution FLOPs (2 * prod(out) * prod(kernel_spatial) * in_features),
  - HBM bytes at op boundaries (operands + result, fusion-boundary only),
  - collective bytes per op class (all-gather / all-reduce / reduce-scatter
    / all-to-all / collective-permute), operand sizes summed per the
    assignment's definition.

All quantities are PER DEVICE because the HLO is the per-device SPMD
program; roofline terms therefore divide by per-chip peak rates.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (3D-torus; one link's worth as the serial bottleneck model).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable

__all__ = [
    "HW",
    "HloCost",
    "analyze_hlo",
    "roofline_terms",
    "model_flops",
]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_info(type_str: str) -> tuple[int, int]:
    """-> (total_bytes, n_elements) over all array components of the type."""
    total_b = total_e = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class _CompStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    # (callee, multiplier) edges
    calls: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collective_bytes: dict  # per collective class
    n_collectives: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": dict(self.collective_bytes),
            "total_collective_bytes": self.total_collective_bytes,
        }


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(r"(?:calls|body|to_apply|condition)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def analyze_hlo(text: str) -> HloCost:
    comps: dict[str, _CompStats] = {}
    entry: str | None = None
    cur: _CompStats | None = None
    cur_name = None
    symbols: dict[str, str] = {}

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.endswith("{"):
            cur_name = hdr.group(1)
            cur = _CompStats()
            comps[cur_name] = cur
            symbols = {}
            if line.startswith("ENTRY"):
                entry = cur_name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        symbols[name] = type_str
        if opcode in _FREE_OPS:
            continue

        out_bytes, out_elems = _shape_info(type_str)
        # operand shapes from symbol table (first paren group only)
        depth = 0
        args_str = ""
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            args_str += ch
        operand_names = _OPERAND_RE.findall(args_str)
        operand_types = [symbols.get(o, "") for o in operand_names]
        op_bytes = sum(_shape_info(t)[0] for t in operand_types)

        # collectives
        is_coll = False
        for coll in _COLLECTIVES:
            if opcode == coll or opcode == coll + "-start":
                cur.collective_bytes[coll] = cur.collective_bytes.get(
                    coll, 0.0
                ) + max(op_bytes, out_bytes if coll == "all-gather" else 0)
                is_coll = True
            elif opcode == coll + "-done":
                is_coll = True  # counted at -start
        if not is_coll:
            cur.bytes_accessed += out_bytes + op_bytes

        if opcode == "dot":
            lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            lhs_dims = _dims_of(operand_types[0]) if operand_types else []
            contract = 1
            if lc and lc.group(1) and lhs_dims:
                for d in lc.group(1).split(","):
                    contract *= lhs_dims[int(d)]
            cur.flops += 2.0 * out_elems * contract
        elif opcode == "convolution":
            rhs_dims = _dims_of(operand_types[1]) if len(operand_types) > 1 else []
            kernel = 1
            for d in rhs_dims[:-1]:
                kernel *= d
            cur.flops += 2.0 * out_elems * kernel

        if opcode in ("while", "fusion", "call", "conditional", "custom-call"):
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            # while: body (and condition, negligible) run `trip` times;
            # fusion/call/conditional have no trip_count -> mult 1.
            # Fusion-internal ops never touch HBM: count their dots'
            # FLOPs but not their bytes (the fusion op itself already
            # contributed its boundary bytes above).
            is_fusion = opcode == "fusion"
            for callee in _CALLED_RE.findall(line):
                cur.calls.append((callee, trip, is_fusion))

    if entry is None:
        raise ValueError("no ENTRY computation found in HLO")

    memo: dict[str, tuple[float, float, dict]] = {}

    def walk(name: str) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        st = comps.get(name)
        if st is None:
            return (0.0, 0.0, {})
        memo[name] = (0.0, 0.0, {})  # cycle guard
        fl, by = st.flops, st.bytes_accessed
        cb = dict(st.collective_bytes)
        for callee, mult, is_fusion in st.calls:
            cfl, cby, ccb = walk(callee)
            fl += mult * cfl
            if not is_fusion:
                by += mult * cby
            for k, v in ccb.items():
                cb[k] = cb.get(k, 0.0) + mult * v
        memo[name] = (fl, by, cb)
        return memo[name]

    fl, by, cb = walk(entry)
    n_coll = sum(1 for c in comps.values() for _ in c.collective_bytes)
    return HloCost(
        flops=fl, bytes_accessed=by, collective_bytes=cb, n_collectives=n_coll
    )


# ----------------------------------------------------------------------
# Roofline terms
# ----------------------------------------------------------------------


def roofline_terms(cost: HloCost, hw: HW = HW()) -> dict:
    """Per-device time lower bounds for the three roofline terms."""
    t_c = cost.flops / hw.peak_flops
    t_m = cost.bytes_accessed / hw.hbm_bw
    t_x = cost.total_collective_bytes / hw.ici_bw
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(t_c, t_m, t_x)
    terms["roofline_fraction_compute"] = t_c / total if total else 0.0
    return terms


# ----------------------------------------------------------------------
# Analytic MODEL_FLOPS (6·N·D, N_active for MoE)
# ----------------------------------------------------------------------


def active_params(cfg) -> int:
    """Matmul parameters touched per token (MoE: shared + top-k routed),
    excluding embeddings/lm-head (per the 6ND convention)."""
    from repro.core.pixelfly import param_count
    from repro.models.layers import AttnSpec, MlpSpec
    from repro.models.moe import MoeSpec
    from repro.models.ssm import SsmSpec

    total = 0
    for g in cfg.layer_groups():
        per_layer = 0
        if g.kind in ("dense", "shared_attn", "moe"):
            a = AttnSpec(cfg)
            per_layer += sum(
                param_count(s) for s in (a.wq, a.wk, a.wv, a.wo)
            )
        if g.kind in ("dense", "shared_attn"):
            d_ff = cfg.d_ff
            if g.kind == "dense" and cfg.family == "moe" and cfg.moe_dense_ff:
                d_ff = cfg.moe_dense_ff
            m = MlpSpec(cfg, d_ff)
            per_layer += sum(param_count(s) for s in (m.wg, m.wu, m.wd))
        if g.kind == "moe":
            spec = MoeSpec(cfg)
            if cfg.sparse:
                pat_gu, rank_gu = spec.sparse_layout(cfg.d_model, spec.d_ff)
                pat_d, rank_d = spec.sparse_layout(spec.d_ff, cfg.d_model)
                per_exp = (
                    2 * (pat_gu.nnz + rank_gu * (cfg.d_model + spec.d_ff))
                    + pat_d.nnz + rank_d * (cfg.d_model + spec.d_ff)
                )
            else:
                per_exp = 3 * cfg.d_model * spec.d_ff
            per_layer += cfg.moe_top_k * per_exp
            if cfg.moe_num_shared:
                m = MlpSpec(cfg, cfg.moe_num_shared * spec.d_ff)
                per_layer += sum(param_count(s) for s in (m.wg, m.wu, m.wd))
            per_layer += cfg.d_model * spec.n_exp  # router
        if g.kind == "ssm":
            s = SsmSpec(cfg)
            per_layer += param_count(s.in_proj) + param_count(s.out_proj)
            per_layer += s.conv_dim * cfg.ssm_conv
        total += per_layer * g.count
    return total


def model_flops(cfg, n_tokens: int, *, backward: bool = True) -> float:
    """6·N_active·D (training) or 2·N_active·D (inference)."""
    mult = 6.0 if backward else 2.0
    return mult * active_params(cfg) * n_tokens
