"""jaxlint: repo-specific JAX/Pallas static analysis.

An AST-based linter whose rules are keyed to this repo's real bug
history — each rule encodes an invariant that a past PR broke and a
reviewer had to hand-find:

  JL001  implicit host sync in a ``@hot_path`` function (``.item()``,
         ``int()/float()/bool()/np.asarray`` on device values,
         ``jax.device_get``, implicit ``__bool__`` via ``if``/``while``
         on arrays).  The PR 6 regression: a per-step host upload /
         sync serializes the device stream once per decode step.
  JL002  Python control flow or iteration over tracer values inside a
         ``jit``-decorated function — a trace-time concretization error
         waiting for the first non-warmup shape.
  JL003  recompile hazards: ``jax.jit`` constructed per call (inside a
         non-``__init__`` function body), immediately-invoked
         ``jax.jit(f)(x)``, container literals with static leaves at
         known-jit call sites, f-strings over tracers, and jit'd
         lambdas closing over locally-computed shapes.  The PR 3
         regression: a mid-traffic recompile hiccup.
  JL004  Pallas structural checks on kernel files: BlockSpec index-map
         arity must equal grid rank + ``num_scalar_prefetch``,
         validity/position refs must actually mask (the trash page
         must not be read unmasked), and the kernel invocation must
         pass scalar-prefetch operands first (operand count =
         ``num_scalar_prefetch + len(in_specs)``).
  JL005  in-jit paged-pool writes (``pool.at[...].set/add``) must pin
         the pool layout via ``constrain_paged_pool`` /
         ``constrain_pools`` / ``with_sharding_constraint`` in the same
         function.  The PR 7 regression: an unconstrained sharded pool
         write made XLA round-trip the whole KV pool.
  JL006  observability recorder call (``tracer.begin/end/instant``,
         ``stats.record_*``, ``metrics...inc/observe/set``) inside a
         ``jit``-decorated function.  Recorders are host-side Python:
         under jit they fire once at trace time and never again, so the
         metric silently under-counts by (steps - compiles) — record
         around the jit boundary instead.
  JL000  malformed suppression: a ``# jaxlint: disable=...`` comment
         without a non-empty ``-- reason`` string.

Suppression: append ``# jaxlint: disable=JL001 -- why this is fine`` to
the offending line (or the line above).  The reason is mandatory; a
reasonless disable is itself a finding (JL000) and suppresses nothing.

Accepted findings that cannot be fixed live in ``jaxlint_baseline.txt``
(one fingerprint per line, ``fingerprint # reason``).  Fingerprints are
line-number-independent (path : rule : function : normalized source), so
the baseline survives unrelated edits but goes stale — and errors — the
moment the flagged code changes.  ``--check-baseline-growth`` compares
the baseline against the committed copy and fails on new entries: the
baseline only shrinks.

CLI::

    python -m repro.analysis.jaxlint src/
    python -m repro.analysis.jaxlint src/ --baseline jaxlint_baseline.txt
    python -m repro.analysis.jaxlint --list-rules

Scope notes (honest limits): taint tracking is per-function and
name-based — it follows assignments from ``jnp.*`` / ``jax.*`` calls and
from jit-built class attributes (``self._decode = jax.jit(...)``), but
does not cross function boundaries; JL002 applies to literally
jit-decorated defs (functions merely *called* under jit are covered at
runtime by ``repro.analysis.guards``); JL004 skips call sites whose
grids / spec lists it cannot resolve to literals.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import subprocess
import sys
from pathlib import Path

__all__ = [
    "Finding",
    "lint_source",
    "lint_paths",
    "load_baseline",
    "main",
    "RULES",
]

RULES = {
    "JL000": "malformed jaxlint suppression (missing '-- reason')",
    "JL001": "implicit host sync in a @hot_path function",
    "JL002": "Python control flow over tracer values inside jit",
    "JL003": "recompile hazard at a jit boundary",
    "JL004": "Pallas kernel structural violation",
    "JL005": "in-jit paged-pool write without a sharding constraint",
    "JL006": "observability recorder call inside a jit-decorated function",
}

HINTS = {
    "JL000": "write '# jaxlint: disable=JLxxx -- <non-empty reason>'",
    "JL001": "batch host reads into one explicit jax.device_get per step, "
    "or hoist the conversion out of the hot path",
    "JL002": "use jax.lax.cond/while_loop/fori_loop, or lift the value to "
    "a static argument",
    "JL003": "construct jits once (module scope or __init__) and mark "
    "non-array arguments static",
    "JL004": "index maps take grid indices then scalar-prefetch refs; "
    "mask trash-page reads by logical position; prefetch operands first",
    "JL005": "route the write through constrain_paged_pool / "
    "sharding.constrain_pools so GSPMD keeps the pool layout in place",
    "JL006": "recorders run at trace time under jit (once per compile, "
    "not per call) — move the record to the host-side caller of the "
    "jit'd function",
}

_OBS_METHODS = {"begin", "end", "instant", "complete", "observe", "inc",
                "set"}
_OBS_BASE_RE = re.compile(r"(^|_)(tracer|metrics|stats|registry)$")
_POOL_NAMES = {"kc", "vc", "k_pages", "v_pages"}
_POOL_CONTAINERS = {"cache", "caches", "pool", "pools"}
_POOL_TREE_ARGS = {"pool", "pools", "buffers", "caches"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
_MASK_PARAM_RE = re.compile(r"(^|_)(valid|keep|pos|mask)")
_SUPPRESS_RE = re.compile(
    r"#\s*jaxlint:\s*disable=([A-Za-z0-9,\s]+?)\s*(?:--\s*(.*?))?\s*$"
)


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str
    func: str = "<module>"
    snippet: str = ""

    @property
    def fingerprint(self) -> str:
        norm = " ".join(self.snippet.split())
        return f"{self.path}:{self.code}:{self.func}:{norm}"

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: {self.code} "
            f"{self.message}\n    hint: {HINTS[self.code]}"
        )


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------


def _full_name(node: ast.AST) -> str:
    """Dotted name of a Name/Attribute chain ('' when not a pure chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_expr(node: ast.AST) -> bool:
    """True for ``jax.jit(...)`` / ``pjit(...)`` calls, including
    ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return False
    name = _full_name(node.func)
    if name in ("jax.jit", "jit", "jax.pjit", "pjit"):
        return True
    if name in ("functools.partial", "partial") and node.args:
        return _full_name(node.args[0]) in ("jax.jit", "jit")
    return False


def _is_jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        if _full_name(dec) in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call) and _is_jit_expr(dec):
            return True
        if isinstance(dec, ast.Call) and _full_name(dec.func) in (
            "functools.partial",
            "partial",
        ):
            if dec.args and _full_name(dec.args[0]) in ("jax.jit", "jit"):
                return True
    return False


def _is_hot_path(fn: ast.FunctionDef) -> bool:
    return any(
        _full_name(d).split(".")[-1] == "hot_path" for d in fn.decorator_list
    )


def _arrayish_annotation(ann: ast.AST | None) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return "Array" in ann.value or "ndarray" in ann.value
    name = _full_name(ann)
    return "Array" in name or "ndarray" in name


def _uses_shape(node: ast.AST) -> bool:
    return any(
        isinstance(n, ast.Attribute) and n.attr == "shape"
        for n in ast.walk(node)
    )


# ---------------------------------------------------------------------------
# per-function taint: which local names hold device values / tracers
# ---------------------------------------------------------------------------


class _Taint:
    """Name-based forward dataflow over one function body.

    ``device`` holds local names believed to reference on-device arrays
    (or tracers).  Sources: ``jnp.*`` / ``jax.*`` call results, calls
    through jit-built attributes (``self._decode(...)``), and — for jit
    functions — parameters with array-ish annotations.  Conversions
    (``jax.device_get``, ``np.asarray``, ``int()``...) produce host
    values.  Two passes over the body propagate loop-carried taint.
    """

    _HOST_CALLS = {
        "jax.device_get",
        "np.asarray",
        "np.array",
        "int",
        "float",
        "bool",
        "len",
        "str",
        "list",
        "tuple",
        "range",
        "time.perf_counter",
    }

    def __init__(self, jit_attrs: set[str], seed: set[str] | None = None):
        self.jit_attrs = jit_attrs
        self.device: set[str] = set(seed or ())

    def run(self, fn: ast.FunctionDef) -> None:
        for _ in range(2):  # fixpoint-ish: covers loop-carried names
            for stmt in fn.body:
                self._stmt(stmt)

    # -- classification ------------------------------------------------

    def is_device(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.device
        if isinstance(node, ast.Subscript):
            return self.is_device(node.value)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.is_device(node.value)
        if isinstance(node, ast.Call):
            return self._call_is_device(node)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_device(e) for e in node.elts)
        if isinstance(node, ast.BinOp):
            return self.is_device(node.left) or self.is_device(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_device(node.operand)
        if isinstance(node, ast.Compare):
            # `x is None` and friends produce Python bools statically
            if all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops
            ):
                return False
            return self.is_device(node.left) or any(
                self.is_device(c) for c in node.comparators
            )
        if isinstance(node, ast.BoolOp):
            return any(self.is_device(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.is_device(node.body) or self.is_device(node.orelse)
        return False

    def _call_is_device(self, call: ast.Call) -> bool:
        name = _full_name(call.func)
        if name in self._HOST_CALLS or name.startswith("np."):
            return False
        if name == "isinstance":
            return False
        if name.startswith("jnp.") or name.startswith("jax.numpy"):
            return True
        if name.startswith("self.") and name.count(".") == 1:
            return name.split(".", 1)[1] in self.jit_attrs
        if name in ("jax.block_until_ready",):
            return bool(call.args) and self.is_device(call.args[0])
        if name.startswith("jax.lax.") or name == "jax.device_put":
            return True
        # method on a device value (x.astype(...), x.reshape(...))
        if isinstance(call.func, ast.Attribute) and self.is_device(
            call.func.value
        ):
            return True
        return False

    # -- statement walk ------------------------------------------------

    def _assign_target(self, target: ast.AST, is_dev: bool) -> None:
        if isinstance(target, ast.Name):
            if is_dev:
                self.device.add(target.id)
            else:
                self.device.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._assign_target(el, is_dev)
        # attribute/subscript targets: no local name to taint

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            val_dev = self.is_device(stmt.value)
            if (
                len(stmt.targets) == 1
                and isinstance(stmt.targets[0], (ast.Tuple, ast.List))
                and isinstance(stmt.value, (ast.Tuple, ast.List))
                and len(stmt.targets[0].elts) == len(stmt.value.elts)
            ):
                for t, v in zip(stmt.targets[0].elts, stmt.value.elts):
                    self._assign_target(t, self.is_device(v))
                return
            for t in stmt.targets:
                self._assign_target(t, val_dev)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            if stmt.value is not None:
                self._assign_target(stmt.target, self.is_device(stmt.value))
        elif isinstance(stmt, (ast.If, ast.While)):
            for s in stmt.body:
                self._stmt(s)
            for s in stmt.orelse:
                self._stmt(s)
        elif isinstance(stmt, ast.For):
            self._assign_target(stmt.target, self.is_device(stmt.iter))
            for s in stmt.body:
                self._stmt(s)
            for s in stmt.orelse:
                self._stmt(s)
        elif isinstance(stmt, ast.With):
            for s in stmt.body:
                self._stmt(s)
        elif isinstance(stmt, ast.Try):
            for block in (stmt.body, stmt.orelse, stmt.finalbody):
                for s in block:
                    self._stmt(s)
            for h in stmt.handlers:
                for s in h.body:
                    self._stmt(s)


# ---------------------------------------------------------------------------
# the linter
# ---------------------------------------------------------------------------


class _ModuleLinter:
    def __init__(self, path: str, source: str, *, kernel_file: bool):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.kernel_file = kernel_file
        self.findings: list[Finding] = []
        self.jit_attrs: set[str] = set()  # self.X = jax.jit(...) anywhere
        self.module_jits: set[str] = set()  # module-level jit'd callables
        self.local_defs: dict[str, ast.FunctionDef] = {}

    # -- plumbing ------------------------------------------------------

    def _snippet(self, node: ast.AST) -> str:
        line = getattr(node, "lineno", 1)
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def flag(self, node: ast.AST, code: str, message: str, func: str) -> None:
        self.findings.append(
            Finding(
                path=self.path,
                line=getattr(node, "lineno", 1),
                col=getattr(node, "col_offset", 0),
                code=code,
                message=message,
                func=func,
                snippet=self._snippet(node),
            )
        )

    # -- entry ---------------------------------------------------------

    def run(self) -> list[Finding]:
        try:
            tree = ast.parse(self.source, filename=self.path)
        except SyntaxError as exc:
            self.findings.append(
                Finding(
                    path=self.path,
                    line=exc.lineno or 1,
                    col=exc.offset or 0,
                    code="JL000",
                    message=f"syntax error: {exc.msg}",
                )
            )
            return self.findings

        self._collect(tree)
        self._walk_functions(tree, qual="")
        self._check_module_level_jl003(tree)
        self._walk_jl005(tree, qual="")
        if self.kernel_file:
            self._check_pallas(tree)
        self._apply_suppressions()
        return self.findings

    def _collect(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and _is_jit_expr(node.value):
                for t in node.targets:
                    name = _full_name(t)
                    if name.startswith("self."):
                        self.jit_attrs.add(name.split(".", 1)[1])
                    elif isinstance(t, ast.Name):
                        self.module_jits.add(t.id)
            if isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef)
            ) and _is_jit_decorated(node):
                self.module_jits.add(node.name)
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                # nested defs included: Pallas index maps usually live
                # inside the kernel builder (first binding wins on the
                # rare name collision)
                self.local_defs.setdefault(node.name, node)

    def _walk_functions(self, scope: ast.AST, qual: str) -> None:
        for node in ast.iter_child_nodes(scope):
            if isinstance(node, ast.ClassDef):
                self._walk_functions(node, f"{qual}{node.name}.")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = f"{qual}{node.name}"
                self._lint_function(node, name)
                self._walk_functions(node, f"{name}.")

    # -- JL001 ---------------------------------------------------------

    def _lint_function(self, fn: ast.FunctionDef, qual: str) -> None:
        hot = _is_hot_path(fn)
        jit = _is_jit_decorated(fn)
        if hot:
            taint = _Taint(self.jit_attrs)
            taint.run(fn)
            self._check_hot_path(fn, qual, taint)
        if jit:
            seed = {
                a.arg
                for a in fn.args.args + fn.args.kwonlyargs
                if _arrayish_annotation(a.annotation)
            }
            taint = _Taint(self.jit_attrs, seed=seed)
            taint.run(fn)
            self._check_jit_body(fn, qual, taint)
            self._check_jl006(fn, qual)
        self._check_jl003_in_function(fn, qual)

    def _own_nodes(self, fn: ast.FunctionDef):
        """Walk fn's body without descending into nested defs."""
        stack: list[ast.AST] = list(fn.body)
        while stack:
            node = stack.pop()
            yield node
            if isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
            ):
                continue
            stack.extend(ast.iter_child_nodes(node))

    def _check_hot_path(
        self, fn: ast.FunctionDef, qual: str, taint: _Taint
    ) -> None:
        for node in self._own_nodes(fn):
            if isinstance(node, ast.Call):
                name = _full_name(node.func)
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"
                ):
                    self.flag(
                        node,
                        "JL001",
                        ".item() forces a per-call device sync on the "
                        "hot path",
                        qual,
                    )
                elif name == "jax.device_get":
                    self.flag(
                        node,
                        "JL001",
                        "jax.device_get on the hot path — syncs are "
                        "budgeted at one batched fetch per step "
                        "(suppress with a reason where sanctioned)",
                        qual,
                    )
                elif name in ("int", "float", "bool") and any(
                    taint.is_device(a) for a in node.args
                ):
                    self.flag(
                        node,
                        "JL001",
                        f"{name}() on a device value forces an implicit "
                        "host sync",
                        qual,
                    )
                elif name in ("np.asarray", "np.array") and any(
                    taint.is_device(a) for a in node.args
                ):
                    self.flag(
                        node,
                        "JL001",
                        f"{name}() on a device value is an implicit "
                        "device->host transfer",
                        qual,
                    )
                elif name in ("jax.tree.map", "jax.tree_map") and any(
                    _full_name(a) in ("np.asarray", "np.array")
                    for a in node.args
                ):
                    self.flag(
                        node,
                        "JL001",
                        "mapping np.asarray over a device tree syncs "
                        "once per leaf",
                        qual,
                    )
            elif isinstance(node, (ast.If, ast.While)):
                if taint.is_device(node.test):
                    self.flag(
                        node,
                        "JL001",
                        "branching on a device value triggers implicit "
                        "__bool__ (a blocking sync)",
                        qual,
                    )

    # -- JL002 ---------------------------------------------------------

    def _check_jit_body(
        self, fn: ast.FunctionDef, qual: str, taint: _Taint
    ) -> None:
        for node in self._own_nodes(fn):
            if isinstance(node, (ast.If, ast.While)):
                if taint.is_device(node.test):
                    kw = "while" if isinstance(node, ast.While) else "if"
                    self.flag(
                        node,
                        "JL002",
                        f"`{kw}` over a tracer inside jit concretizes at "
                        "trace time",
                        qual,
                    )
            elif isinstance(node, ast.For):
                it = node.iter
                if isinstance(it, ast.Call) and _full_name(it.func) in (
                    "range",
                    "enumerate",
                    "zip",
                    "len",
                ):
                    if not any(taint.is_device(a) for a in it.args):
                        continue
                if taint.is_device(it):
                    self.flag(
                        node,
                        "JL002",
                        "Python iteration over a tracer inside jit "
                        "unrolls (or fails) at trace time",
                        qual,
                    )
            elif isinstance(node, ast.JoinedStr):
                for part in node.values:
                    if isinstance(
                        part, ast.FormattedValue
                    ) and taint.is_device(part.value):
                        self.flag(
                            node,
                            "JL002",
                            "f-string over a tracer concretizes it at "
                            "trace time",
                            qual,
                        )
                        break

    # -- JL006 ---------------------------------------------------------

    def _check_jl006(self, fn: ast.FunctionDef, qual: str) -> None:
        """Recorder calls under jit run at trace time, not per call —
        the counter/span silently freezes after the first compile.
        Detection is name-based: a method from the recorder surface
        (begin/end/instant/complete/observe/inc/set or ``record_*``)
        invoked on a base whose last component looks like an obs object
        (``...tracer`` / ``...metrics`` / ``...stats`` /
        ``...registry``)."""
        for node in self._own_nodes(fn):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
            ):
                continue
            meth = node.func.attr
            if meth not in _OBS_METHODS and not meth.startswith("record_"):
                continue
            base = _full_name(node.func.value)
            if not base or not _OBS_BASE_RE.search(base.split(".")[-1]):
                continue
            self.flag(
                node,
                "JL006",
                f"`{base}.{meth}(...)` inside a jit-decorated function "
                "records at trace time only — once per compile, never "
                "per step",
                qual,
            )

    # -- JL003 ---------------------------------------------------------

    def _check_jl003_in_function(
        self, fn: ast.FunctionDef, qual: str
    ) -> None:
        # jit construction per call: anywhere except __init__ (engines
        # legitimately build their program variants there) — module
        # scope is handled separately.
        if fn.name != "__init__":
            for node in self._own_nodes(fn):
                if isinstance(node, ast.Call) and _is_jit_expr(node):
                    self.flag(
                        node,
                        "JL003",
                        "jax.jit constructed inside a function body: a "
                        "fresh jit wrapper per call defeats the "
                        "compile cache",
                        qual,
                    )
        # shape-closure lambdas: jit(lambda ...) capturing a local that
        # was assigned from a .shape expression silently specializes.
        shape_locals = {
            _full_name(t)
            for node in self._own_nodes(fn)
            if isinstance(node, ast.Assign) and _uses_shape(node.value)
            for t in node.targets
            if isinstance(t, ast.Name)
        }
        if shape_locals:
            for node in self._own_nodes(fn):
                if not (isinstance(node, ast.Call) and _is_jit_expr(node)):
                    continue
                for arg in node.args:
                    if not isinstance(arg, ast.Lambda):
                        continue
                    params = {a.arg for a in arg.args.args}
                    captured = {
                        n.id
                        for n in ast.walk(arg.body)
                        if isinstance(n, ast.Name)
                        and n.id in shape_locals
                        and n.id not in params
                    }
                    if captured:
                        self.flag(
                            node,
                            "JL003",
                            "jit'd lambda closes over locally-computed "
                            f"shape(s) {sorted(captured)} — the program "
                            "silently specializes per shape",
                            qual,
                        )
        self._check_jit_callsites(fn, qual)

    def _check_module_level_jl003(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Call)
                and _is_jit_expr(node.func)
            ):
                self.flag(
                    node,
                    "JL003",
                    "immediately-invoked jax.jit(f)(...) builds and "
                    "drops the wrapper: the compile cache entry dies "
                    "with it",
                    "<module>",
                )

    def _check_jit_callsites(self, fn: ast.FunctionDef, qual: str) -> None:
        """Container literals with static-ish leaves at known-jit call
        sites: a dict/list whose leaves are Python constants hashes into
        the pytree structure, so every distinct value recompiles."""
        known = self.module_jits | {f"self.{a}" for a in self.jit_attrs}
        for node in self._own_nodes(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _full_name(node.func)
            if name not in known:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, (ast.Dict, ast.List, ast.Set)):
                    elts = (
                        list(arg.values)
                        if isinstance(arg, ast.Dict)
                        else list(arg.elts)
                    )
                    if any(
                        isinstance(e, (ast.Constant, ast.JoinedStr))
                        for e in elts
                    ):
                        self.flag(
                            node,
                            "JL003",
                            f"call to jit'd `{name}` passes a container "
                            "literal with constant leaves — each "
                            "distinct value recompiles; mark it static "
                            "or pass arrays",
                            qual,
                        )
                        break

    # -- JL004 ---------------------------------------------------------

    def _check_pallas(self, tree: ast.Module) -> None:
        # grid is often a local name (`grid = (b, hk, w)`): resolve
        # tuple-literal assignments anywhere in the module (names are
        # function-local in practice, collisions would only widen the
        # skip set).
        grid_ranks: dict[str, int] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and isinstance(
                node.value, (ast.Tuple, ast.List)
            ):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        grid_ranks[t.id] = len(node.value.elts)

        for call in ast.walk(tree):
            if not isinstance(call, ast.Call):
                continue
            name = _full_name(call.func)
            if name.split(".")[-1] != "PrefetchScalarGridSpec":
                continue
            kwargs = {k.arg: k.value for k in call.keywords if k.arg}
            n_pref = kwargs.get("num_scalar_prefetch")
            grid = kwargs.get("grid")
            in_specs = kwargs.get("in_specs")
            if not isinstance(n_pref, ast.Constant):
                continue  # dynamic prefetch count: unresolvable
            k = int(n_pref.value)
            rank = None
            if isinstance(grid, (ast.Tuple, ast.List)):
                rank = len(grid.elts)
            elif isinstance(grid, ast.Name):
                rank = grid_ranks.get(grid.id)
            if rank is not None and in_specs is not None:
                self._check_index_maps(in_specs, rank, k)
            out_specs = kwargs.get("out_specs")
            if rank is not None and out_specs is not None:
                self._check_index_maps(out_specs, rank, k)
            if isinstance(in_specs, (ast.Tuple, ast.List)):
                self._check_operand_count(tree, call, k, len(in_specs.elts))

        self._check_mask_refs(tree)

    def _index_map_arity(self, spec: ast.Call) -> tuple[ast.AST, int] | None:
        cand = None
        for kw in spec.keywords:
            if kw.arg == "index_map":
                cand = kw.value
        if cand is None and len(spec.args) >= 2:
            cand = spec.args[1]
        if cand is None:
            return None
        if isinstance(cand, ast.Lambda):
            return cand, len(cand.args.args)
        if isinstance(cand, ast.Name) and cand.id in self.local_defs:
            d = self.local_defs[cand.id]
            return cand, len(d.args.args)
        return None

    def _check_index_maps(self, specs: ast.AST, rank: int, k: int) -> None:
        spec_nodes = (
            specs.elts if isinstance(specs, (ast.Tuple, ast.List)) else [specs]
        )
        for spec in spec_nodes:
            if not (
                isinstance(spec, ast.Call)
                and _full_name(spec.func).split(".")[-1] == "BlockSpec"
            ):
                continue
            got = self._index_map_arity(spec)
            if got is None:
                continue
            node, arity = got
            want = rank + k
            if arity != want:
                self.flag(
                    spec,
                    "JL004",
                    f"BlockSpec index map takes {arity} args but the "
                    f"grid has rank {rank} with {k} scalar-prefetch "
                    f"operand(s): expected {want} (grid indices first, "
                    "then prefetch refs)",
                    "<module>",
                )

    def _check_operand_count(
        self, tree: ast.Module, spec_call: ast.Call, k: int, n_in: int
    ) -> None:
        """The pallas_call invocation must pass prefetch operands first:
        operand count == num_scalar_prefetch + len(in_specs)."""
        for call in ast.walk(tree):
            if not (
                isinstance(call, ast.Call)
                and isinstance(call.func, ast.Call)
                and _full_name(call.func.func).split(".")[-1] == "pallas_call"
            ):
                continue
            uses_spec = any(n is spec_call for n in ast.walk(call.func))
            if not uses_spec:
                continue
            if any(
                isinstance(a, ast.Starred) for a in call.args
            ) or call.keywords:
                continue  # dynamic operand list: unresolvable
            got = len(call.args)
            want = k + n_in
            if got != want:
                self.flag(
                    call,
                    "JL004",
                    f"pallas_call invocation passes {got} operand(s) "
                    f"but the grid spec declares {k} scalar-prefetch + "
                    f"{n_in} in_specs = {want} (prefetch operands must "
                    "come first)",
                    "<module>",
                )

    def _kernel_body_names(self, tree: ast.Module) -> set[str]:
        """Names of functions passed (possibly through functools.partial,
        inline or via a local binding) as the first pallas_call arg."""
        partial_of: dict[str, str] = {}
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)
                and _full_name(node.value.func)
                in ("functools.partial", "partial")
                and node.value.args
            ):
                tgt = _full_name(node.value.args[0])
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        partial_of[t.id] = tgt
        names: set[str] = set()
        for node in ast.walk(tree):
            if not (
                isinstance(node, ast.Call)
                and _full_name(node.func).split(".")[-1] == "pallas_call"
                and node.args
            ):
                continue
            a0 = node.args[0]
            if (
                isinstance(a0, ast.Call)
                and _full_name(a0.func) in ("functools.partial", "partial")
                and a0.args
            ):
                nm = _full_name(a0.args[0])
            else:
                nm = _full_name(a0)
            nm = partial_of.get(nm, nm)
            if nm:
                names.add(nm.split(".")[-1])
        return names

    def _check_mask_refs(self, tree: ast.Module) -> None:
        """A *kernel-body* parameter named like a validity/position ref
        that is never used in a comparison or a pl.when/jnp.where guard
        means the trash page (or bucket padding) is being read unmasked.
        Index maps take the same prefetch refs but only compute block
        indices, so only the function(s) actually passed to pallas_call
        are held to this."""
        bodies = self._kernel_body_names(tree)
        for fn in ast.walk(tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if fn.name not in bodies:
                continue
            mask_params = [
                a.arg
                for a in fn.args.args
                if _MASK_PARAM_RE.search(a.arg) and a.arg.endswith("_ref")
            ]
            if not mask_params:
                continue
            guarded: set[str] = set()
            for node in ast.walk(fn):
                names = set()
                if isinstance(node, ast.Compare):
                    names = {
                        n.id
                        for sub in [node.left, *node.comparators]
                        for n in ast.walk(sub)
                        if isinstance(n, ast.Name)
                    }
                elif isinstance(node, ast.Call) and _full_name(
                    node.func
                ).split(".")[-1] in ("when", "where", "select"):
                    names = {
                        n.id
                        for a in node.args
                        for n in ast.walk(a)
                        if isinstance(n, ast.Name)
                    }
                guarded |= names & set(mask_params)
            for p in mask_params:
                if p not in guarded:
                    self.flag(
                        fn,
                        "JL004",
                        f"kernel `{fn.name}` takes validity ref `{p}` "
                        "but never masks with it — trash-page / "
                        "padding lanes leak into the output",
                        fn.name,
                    )

    # -- JL005 ---------------------------------------------------------

    def _pool_params(self, fn_or_lambda, tree_call: ast.Call) -> set[str]:
        """Params of a callable passed to jax.tree.map whose sibling
        tree args look like paged pools."""
        poolish = False
        for a in tree_call.args[1:]:
            name = _full_name(a)
            base = name.split(".")[-1] if name else ""
            if base in _POOL_TREE_ARGS:
                poolish = True
        if not poolish:
            return set()
        args = fn_or_lambda.args.args
        return {a.arg for a in args}

    def _is_pool_expr(self, node: ast.AST, pool_params: set[str]) -> bool:
        if isinstance(node, ast.Name):
            return node.id in _POOL_NAMES or node.id in pool_params
        if isinstance(node, ast.Subscript):
            base = node.value
            return (
                isinstance(base, ast.Name) and base.id in _POOL_CONTAINERS
            )
        if isinstance(node, ast.Attribute):
            return node.attr == "buffers"
        return False

    def _check_jl005(
        self, fn: ast.FunctionDef, qual: str, pool_params: set[str]
    ) -> None:
        has_constraint = any(
            isinstance(n, ast.Call)
            and (
                "constrain" in _full_name(n.func).split(".")[-1]
                or _full_name(n.func).endswith("with_sharding_constraint")
            )
            for n in ast.walk(fn)
        )
        if has_constraint:
            return
        for node in ast.walk(fn):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("set", "add")
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"
            ):
                continue
            target = node.func.value.value.value
            if self._is_pool_expr(target, pool_params):
                self.flag(
                    node,
                    "JL005",
                    "paged-pool write without a sharding constraint in "
                    "the same function: GSPMD may materialize and "
                    "reshard the whole pool around this .at[...] "
                    "update (the PR 7 bug)",
                    qual,
                )

    def _walk_jl005(self, scope: ast.AST, qual: str) -> None:
        """JL005 needs tree.map context: lambdas passed to jax.tree.map
        inherit pool taint from sibling args, and the nearest enclosing
        def must carry the constraint."""
        for node in ast.iter_child_nodes(scope):
            if isinstance(node, ast.ClassDef):
                self._walk_jl005(node, f"{qual}{node.name}.")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = f"{qual}{node.name}"
                pool_params: set[str] = set()
                for n in ast.walk(node):
                    if isinstance(n, ast.Call) and _full_name(n.func) in (
                        "jax.tree.map",
                        "jax.tree_map",
                        "jax.tree_util.tree_map",
                    ):
                        if n.args and isinstance(n.args[0], ast.Lambda):
                            pool_params |= self._pool_params(n.args[0], n)
                self._check_jl005(node, name, pool_params)
                self._walk_jl005(node, f"{name}.")

    # -- suppression ---------------------------------------------------

    def _apply_suppressions(self) -> None:
        sup: dict[int, set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
            reason = (m.group(2) or "").strip()
            if not reason:
                self.findings.append(
                    Finding(
                        path=self.path,
                        line=i,
                        col=0,
                        code="JL000",
                        message="suppression without a reason ('-- why') "
                        "suppresses nothing",
                        func="<comment>",
                        snippet=line.strip(),
                    )
                )
                continue
            # applies to findings on this line and the next (comment-
            # above style)
            sup.setdefault(i, set()).update(codes)
            sup.setdefault(i + 1, set()).update(codes)
        if sup:
            self.findings = [
                f
                for f in self.findings
                if f.code == "JL000" or f.code not in sup.get(f.line, set())
            ]


# ---------------------------------------------------------------------------
# public API / CLI
# ---------------------------------------------------------------------------


def _is_kernel_file(path: str, source: str) -> bool:
    return "/kernels/" in path.replace("\\", "/") or "pallas" in source


def lint_source(
    source: str, path: str = "<string>", *, kernel_file: bool | None = None
) -> list[Finding]:
    if kernel_file is None:
        kernel_file = _is_kernel_file(path, source)
    return _ModuleLinter(path, source, kernel_file=kernel_file).run()


def _iter_py_files(paths: list[str]):
    for p in paths:
        pth = Path(p)
        if pth.is_dir():
            yield from sorted(pth.rglob("*.py"))
        elif pth.suffix == ".py":
            yield pth


def lint_paths(paths: list[str]) -> list[Finding]:
    findings: list[Finding] = []
    for f in _iter_py_files(paths):
        src = f.read_text()
        findings.extend(
            lint_source(src, str(f), kernel_file=_is_kernel_file(str(f), src))
        )
    return findings


def load_baseline(path: Path) -> dict[str, str]:
    """``fingerprint # reason`` per line; reasons are mandatory."""
    entries: dict[str, str] = {}
    for i, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        fp, _, reason = line.partition(" # ")
        fp, reason = fp.strip(), reason.strip()
        if not reason:
            raise ValueError(
                f"{path}:{i}: baseline entry without a ' # reason' — "
                "accepted findings must say why they are accepted"
            )
        entries[fp] = reason
    return entries


def _committed_baseline(path: Path) -> set[str] | None:
    """Fingerprints in the committed (HEAD) copy of the baseline, or
    None when HEAD has no such file (first PR introducing it)."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path.name}"],
            cwd=path.parent,
            capture_output=True,
            text=True,
        )
    except OSError:
        return None
    if out.returncode != 0:
        return None
    fps = set()
    for raw in out.stdout.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        fps.add(line.partition(" # ")[0].strip())
    return fps


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.jaxlint",
        description="repo-specific JAX/Pallas static analysis",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="accepted-findings file (fingerprint # reason per line)",
    )
    ap.add_argument(
        "--write-baseline",
        type=Path,
        default=None,
        help="write current findings as a baseline skeleton and exit",
    )
    ap.add_argument(
        "--check-baseline-growth",
        action="store_true",
        help="fail if the baseline gained entries vs the committed copy",
    )
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, desc in sorted(RULES.items()):
            print(f"{code}  {desc}\n       fix: {HINTS[code]}")
        return 0
    if not args.paths:
        ap.error("no paths given")

    findings = lint_paths(args.paths)

    if args.write_baseline is not None:
        lines = [
            "# jaxlint baseline: accepted findings. Every entry needs a",
            "# ' # reason'. This file only shrinks (checked in CI).",
        ]
        for f in sorted(findings, key=lambda f: f.fingerprint):
            lines.append(f"{f.fingerprint} # FIXME-reason")
        args.write_baseline.write_text("\n".join(lines) + "\n")
        print(
            f"wrote {len(findings)} entr(y|ies) to {args.write_baseline}; "
            "replace every FIXME-reason before committing"
        )
        return 0

    baseline: dict[str, str] = {}
    if args.baseline is not None and args.baseline.exists():
        try:
            baseline = load_baseline(args.baseline)
        except ValueError as exc:
            print(f"jaxlint: {exc}", file=sys.stderr)
            return 2

    if args.check_baseline_growth and args.baseline is not None:
        committed = _committed_baseline(args.baseline)
        if committed is not None:
            grown = set(baseline) - committed
            if grown:
                print(
                    "jaxlint: baseline grew by "
                    f"{len(grown)} entr(y|ies) vs the committed copy — "
                    "the baseline only shrinks; fix the finding or "
                    "suppress it inline with a reason:",
                    file=sys.stderr,
                )
                for fp in sorted(grown):
                    print(f"  + {fp}", file=sys.stderr)
                return 1

    fresh = [f for f in findings if f.fingerprint not in baseline]
    matched = {f.fingerprint for f in findings if f.fingerprint in baseline}
    stale = set(baseline) - matched

    rc = 0
    for f in sorted(fresh, key=lambda f: (f.path, f.line)):
        print(f.render())
        rc = 1
    if stale:
        print(
            f"jaxlint: {len(stale)} stale baseline entr(y|ies) — the "
            "flagged code changed or was fixed; remove them (the "
            "baseline only shrinks):",
            file=sys.stderr,
        )
        for fp in sorted(stale):
            print(f"  - {fp}", file=sys.stderr)
        rc = 1
    if rc == 0:
        n_sup = len(findings) - len(fresh)
        print(f"jaxlint: clean ({n_sup} baselined finding(s))")
    else:
        print(
            f"jaxlint: {len(fresh)} finding(s), {len(stale)} stale "
            f"baseline entr(y|ies)",
            file=sys.stderr,
        )
    return rc


if __name__ == "__main__":
    sys.exit(main())
