"""Pallas TPU kernel: block-sparse flash attention over a static schedule.

One pass, online softmax, visiting only the KV blocks named by the static
pixelfly block schedule (local + butterfly strides + global — see
``repro.core.attn_pattern``). This is the TPU analogue of the paper's
Triton block-sparse attention: the *schedule* is the sparsity, so skipped
KV blocks are never read from HBM, giving the O(S·b·log S) key reads per
query block the paper's speedups come from.

Layout: q, k, v are (BH, S, D) with batch*heads collapsed; grid is
(BH, nqb, max_nkv) with the KV-slot axis sequential so the softmax
statistics (m, l) and the output accumulator stay resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["block_sparse_attention_pallas"]

_NEG_INF = float(jnp.finfo(jnp.float32).min)


def _kernel(
    sched_ref,
    valid_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    nkv: int,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
):
    i = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid_ref[i, t] == 1)
    def _visit():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        s = (
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        )  # (bq, bk)
        if causal:
            j = sched_ref[i, t]
            row = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0
            )
            col = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1
            )
            s = jnp.where(col <= row, s, _NEG_INF)
        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        still_masked = m_cur <= _NEG_INF / 2
        alpha = jnp.where(still_masked, 1.0, jnp.exp(m_prev - m_cur))
        p = jnp.where(still_masked, 0.0, jnp.exp(s - m_cur))
        l_prev = l_ref[:, :1]
        l_ref[...] = jnp.broadcast_to(
            l_prev * alpha + p.sum(axis=-1, keepdims=True), l_ref.shape
        )
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(t == nkv - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "causal", "block_q", "block_k", "interpret"),
)
def block_sparse_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_index: jax.Array,
    valid: jax.Array,
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (BH, S, D). kv_index/valid: (nqb, max_nkv) int32."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    nqb, nkv = kv_index.shape
    if sq % block_q or sk % block_k:
        raise ValueError("sequence lengths must be multiples of block sizes")
    if nqb != sq // block_q:
        raise ValueError("schedule rows must match q blocks")

    grid = (bh, nqb, nkv)

    def q_map(bhi, i, t, sched_ref, valid_ref):
        del t
        return (bhi, i, 0)

    def kv_map(bhi, i, t, sched_ref, valid_ref):
        return (bhi, sched_ref[i, t], 0)

    def o_map(bhi, i, t, sched_ref, valid_ref):
        del t
        return (bhi, i, 0)

    kernel = functools.partial(
        _kernel,
        nkv=nkv,
        sm_scale=sm_scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, d), q_map),
                pl.BlockSpec((1, block_k, d), kv_map),
                pl.BlockSpec((1, block_k, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, block_q, d), o_map),
            scratch_shapes=[
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(kv_index, valid, q, k, v)
