"""Public jit'd entry points for the kernels, with backend dispatch.

``impl`` selects between:
  - "pallas"     : the Pallas TPU kernel (compiled; TPU only)
  - "interpret"  : the Pallas kernel body interpreted on CPU (validation)
  - "gather"     : portable pure-jnp path with *sparse* FLOPs (default off
                   TPU; this is what the multi-pod dry-run lowers)
  - "dense_mask" : masked dense GEMM oracle (tests only)

``default_impl()`` picks per-platform so model code never hard-codes one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bsr_attention import block_sparse_attention_pallas
from repro.kernels.bsr_matmul import bsr_matmul_pallas

__all__ = [
    "default_impl",
    "paged_impl_for_mesh",
    "bsr_matmul",
    "block_sparse_attention",
]


def default_impl() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:  # pragma: no cover - no backend at all
        platform = "cpu"
    return "pallas" if platform == "tpu" else "gather"


def paged_impl_for_mesh(impl: str, tp_size: int) -> str:
    """Clamp the paged-attention impl for a tensor-parallel mesh.

    The Pallas page-pool kernel has no SPMD partitioning rule — under
    GSPMD a pallas_call on sharded operands would force a full
    all-gather of the KV pools onto every device (or need a shard_map
    port, a follow-up once real multi-chip TPU is available). The jnp
    gather path is built from ops GSPMD partitions natively, so sharded
    pools always take it; single-device meshes keep the requested impl.
    """
    if tp_size > 1 and impl in ("pallas", "interpret"):
        return "gather"
    return impl


def bsr_matmul(
    x: jax.Array,
    blocks: jax.Array,
    cols: jax.Array,
    *,
    impl: str | None = None,
) -> jax.Array:
    """y = x @ W for a flat-block-butterfly BSR weight.

    x: (..., n_in) -> (..., nb_out * b). Leading dims are flattened for the
    Pallas path and restored after.
    """
    impl = impl or default_impl()
    if impl == "gather":
        if isinstance(cols, np.ndarray):
            # static table -> scatter-free custom-VJP path (§Perf C2)
            return ref.bsr_matmul_custom_vjp(x, blocks, cols)
        return ref.bsr_matmul_gather(x, blocks, cols)
    if impl == "dense_mask":
        return ref.bsr_matmul_dense_mask(x, blocks, cols)
    if impl in ("pallas", "interpret"):
        *lead, n_in = x.shape
        b = int(np.prod(lead)) if lead else 1
        nb_out, _, blk, _ = blocks.shape
        # Pad the flattened batch to a tile multiple.
        bm = min(256, max(8, b))
        pad = (-b) % bm
        x2 = x.reshape(b, n_in)
        if pad:
            x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        y = bsr_matmul_pallas(
            x2, blocks, cols, bm=bm, interpret=(impl == "interpret")
        )
        if pad:
            y = y[:b]
        return y.reshape(*lead, nb_out * blk).astype(x.dtype)
    raise ValueError(f"unknown impl {impl!r}")


def block_sparse_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    schedule,
    *,
    causal: bool = False,
    sm_scale: float | None = None,
    impl: str | None = None,
) -> jax.Array:
    """Block-sparse attention. q,k,v: (B, H, S, D); schedule: BlockSchedule
    (plus its originating boolean block mask, used by the reference path).
    """
    impl = impl or default_impl()
    scale = sm_scale if sm_scale is not None else q.shape[-1] ** -0.5
    if impl in ("gather", "dense_mask"):
        from repro.core.attn_pattern import BlockSchedule  # noqa: F401

        mask = _schedule_to_block_mask(schedule, k.shape[-2])
        return ref.block_sparse_attention_ref(
            q,
            k,
            v,
            mask,
            block_q=schedule.block_q,
            block_k=schedule.block_k,
            causal=causal,
            sm_scale=scale,
        )
    if impl in ("pallas", "interpret"):
        b, h, s, d = q.shape
        sk = k.shape[-2]
        qf = q.reshape(b * h, s, d)
        kf = k.reshape(b * h, sk, d)
        vf = v.reshape(b * h, sk, d)
        out = block_sparse_attention_pallas(
            qf,
            kf,
            vf,
            jnp.asarray(schedule.kv_index),
            jnp.asarray(schedule.valid),
            sm_scale=scale,
            causal=causal,
            block_q=schedule.block_q,
            block_k=schedule.block_k,
            interpret=(impl == "interpret"),
        )
        return out.reshape(b, h, s, d)
    raise ValueError(f"unknown impl {impl!r}")


def _schedule_to_block_mask(schedule, seq_k: int) -> np.ndarray:
    nkb = -(-seq_k // schedule.block_k)
    mask = np.zeros((schedule.nqb, nkb), dtype=bool)
    for i in range(schedule.nqb):
        for t in range(schedule.max_nkv):
            if schedule.valid[i, t]:
                mask[i, schedule.kv_index[i, t]] = True
    return mask
