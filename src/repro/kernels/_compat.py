"""jax version compatibility for the Pallas TPU kernels.

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``;
resolve whichever this jax provides, once, for both kernels.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
