"""Pallas TPU kernel: paged block-sparse decode attention.

One decode step for every serving slot against the block-paged KV cache
(``repro.serving.PagedKVCache``): instead of materializing the scheduled
pages with ``jnp.take`` gathers (the portable path in
``repro.models.layers``), the kernel reads the K/V *pools* directly.
The page-id schedule — the pixelfly butterfly/local/global block ids
each slot's query visits, already mapped through the slot's page table
to physical page ids — is scalar-prefetched, so the BlockSpec index maps
can steer each grid step's DMA at the right pool page before the kernel
body runs. This is the step ROADMAP calls "as fast as the hardware
allows": page-table indirection and the O(b·log n) sparse schedule fused
into one pass over VMEM-resident accumulators.

Layout and masking:
  - grid ``(B, Hk, w)`` — slots x kv-heads x schedule slots; the
    schedule axis is sequential so the online-softmax statistics
    (m, l) and the output accumulator stay resident in VMEM.
  - q is pre-grouped ``(B, Hk, G, D)`` (GQA: G query heads share one
    kv head); each grid step contracts the (G, D) query block with one
    (page, D) pool page.
  - ``logical`` carries the *logical* block id of every schedule slot:
    key position ``logical * page + offset`` is masked against the
    slot's current position, which also neutralizes the shared trash
    page (physical page 0) — idle/unallocated table entries alias it,
    and their logical positions land beyond ``pos``.
  - ``keep`` disables duplicate schedule slots (butterfly XOR
    collisions) so no key is double-counted, mirroring the
    first-occurrence mask of the jnp reference path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["paged_decode_attention_pallas"]

_NEG_INF = float(jnp.finfo(jnp.float32).min)


def _kernel(
    phys_ref,
    logical_ref,
    keep_ref,
    pos_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    w: int,
    page: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(keep_ref[b, t] == 1)
    def _visit():
        q = q_ref[0, 0]  # (G, D)
        k = k_ref[0, :, 0, :]  # (page, D)
        v = v_ref[0, :, 0, :]
        s = (
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        )  # (G, page)
        kpos = logical_ref[b, t] * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1
        )
        s = jnp.where(kpos <= pos_ref[b], s, _NEG_INF)
        m_prev = m_ref[:, :1]  # (G, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        still_masked = m_cur <= _NEG_INF / 2
        alpha = jnp.where(still_masked, 1.0, jnp.exp(m_prev - m_cur))
        p = jnp.where(still_masked, 0.0, jnp.exp(s - m_cur))
        l_prev = l_ref[:, :1]
        l_ref[...] = jnp.broadcast_to(
            l_prev * alpha + p.sum(axis=-1, keepdims=True), l_ref.shape
        )
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(t == w - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def paged_decode_attention_pallas(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    phys: jax.Array,
    logical: jax.Array,
    keep: jax.Array,
    pos: jax.Array,
    *,
    sm_scale: float,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hk, G, D). k_pages, v_pages: (n_pages, page, Hk, D) pools.

    phys/logical/keep: (B, w) int32 — physical page id, logical block id
    and keep flag per schedule slot; pos: (B,) int32 current token
    position per slot. Returns (B, Hk, G, D) in q's dtype.
    """
    b, hk, g, d = q.shape
    _, page, hk_p, d_p = k_pages.shape
    if (hk_p, d_p) != (hk, d):
        raise ValueError("pool head/dim mismatch with q")
    if phys.shape != logical.shape or phys.shape != keep.shape:
        raise ValueError("schedule arrays must share shape (B, w)")
    w = phys.shape[1]

    grid = (b, hk, w)

    def q_map(bi, hi, t, phys_ref, logical_ref, keep_ref, pos_ref):
        del t, phys_ref, logical_ref, keep_ref, pos_ref
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, t, phys_ref, logical_ref, keep_ref, pos_ref):
        del logical_ref, keep_ref, pos_ref
        return (phys_ref[bi, t], 0, hi, 0)

    kernel = functools.partial(_kernel, w=w, page=page, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), q_map),
                pl.BlockSpec((1, page, 1, d), kv_map),
                pl.BlockSpec((1, page, 1, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((g, 128), jnp.float32),
                pltpu.VMEM((g, 128), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(
        phys.astype(jnp.int32),
        logical.astype(jnp.int32),
        keep.astype(jnp.int32),
        pos.astype(jnp.int32),
        q,
        k_pages,
        v_pages,
    )
