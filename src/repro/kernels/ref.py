"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels are validated against
(``tests/test_kernels_*``), and double as the portable execution path on
non-TPU backends (the multi-pod dry-run compiles these — note the
gather+einsum forms have genuinely *sparse* FLOPs in HLO, so the roofline
accounting reflects the paper's compute savings, not a masked-dense proxy).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "bsr_matmul_gather",
    "bsr_matmul_custom_vjp",
    "bsr_matmul_dense_mask",
    "bsr_to_dense",
    "block_sparse_attention_ref",
    "dense_attention_ref",
    "block_mask_to_dense",
]


def bsr_to_dense(blocks: jax.Array, cols: jax.Array, n_in: int) -> jax.Array:
    """Scatter BSR blocks into the dense (n_in, n_out) weight matrix.

    Duplicate column slots sum (matching the gather/einsum semantics).
    """
    nb_out, r, b, _ = blocks.shape
    w = jnp.zeros((n_in // b, nb_out, b, b), blocks.dtype)  # (jblk, iblk, b, b)
    iblk = jnp.arange(nb_out)[:, None].repeat(r, 1)  # (nb_out, r)
    w = w.at[cols.reshape(-1), iblk.reshape(-1)].add(
        blocks.reshape(-1, b, b)
    )
    # (jblk, b, iblk, b) -> (n_in, n_out)
    return w.transpose(0, 2, 1, 3).reshape(n_in, nb_out * b)


def bsr_matmul_gather(
    x: jax.Array, blocks: jax.Array, cols: jax.Array
) -> jax.Array:
    """Gather + einsum BSR matmul: the portable sparse-FLOPs path.

    x (..., n_in), blocks (nb_out, r, b, b), cols (nb_out, r) ->
    y (..., nb_out * b).

    Accumulates one nnz-slot at a time (r <= ~8, unrolled) so the gathered
    activations peak at 1/r of the naive all-slots gather — the Pallas
    kernel streams these from VMEM and materializes none of it.
    """
    *lead, n_in = x.shape
    nb_out, r, b, _ = blocks.shape
    xb = x.reshape(*lead, n_in // b, b)
    y = None
    # NOTE (§Perf C3): no preferred_element_type=f32 here — each dot still
    # accumulates in fp32 inside the MXU, but keeping the HLO value (and
    # therefore every backward cotangent, including the per-layer dx
    # all-reduce) in the model dtype halves TP collective bytes. The
    # r <= 8 inter-slot adds in bf16 cost ~1 ulp.
    for t in range(r):
        xg = jnp.take(xb, cols[:, t], axis=-2)  # (..., nb_out, b)
        yt = jnp.einsum("...ik,ikc->...ic", xg, blocks[:, t])
        y = yt if y is None else y + yt
    return y.reshape(*lead, nb_out * b).astype(x.dtype)


def bsr_matmul_dense_mask(
    x: jax.Array, blocks: jax.Array, cols: jax.Array
) -> jax.Array:
    """Masked-dense oracle (full dense FLOPs) — tests only."""
    n_in = x.shape[-1]
    w = bsr_to_dense(blocks, cols, n_in)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# Custom-VJP BSR matmul: scatter-free backward (§Perf C2/B2)
# ----------------------------------------------------------------------
#
# jax.grad of the gather formulation produces a scatter-add for d_x; under
# SPMD that scatter forces an fp32 all-reduce + "involuntary full
# rematerialization" resharding per sparse linear per microbatch — the
# dominant collective in every TP train cell. But the transpose of a flat
# butterfly is a flat butterfly: d_x = dy @ Wᵀ is just another block
# GATHER with statically transposed tables, and cotangents can stay in the
# model dtype. This mirrors what the Pallas backward kernel does on TPU.


@functools.lru_cache(maxsize=512)
def _bsr_custom_fn(cols_bytes: bytes, nb_out: int, r: int, nb_in: int, b: int):
    from repro.core.butterfly import transpose_tables

    cols = np.frombuffer(cols_bytes, np.int32).reshape(nb_out, r).copy()
    src_i, src_t, valid = transpose_tables(cols, nb_in)
    w = src_i.shape[1]

    def _fwd_impl(x, blocks):
        *lead, n_in = x.shape
        xb = x.reshape(*lead, nb_in, b)
        y = None
        for t in range(r):
            xg = jnp.take(xb, cols[:, t], axis=-2)
            yt = jnp.einsum(
                "...ik,ikc->...ic", xg, blocks[:, t],
                preferred_element_type=jnp.float32,
            )
            y = yt if y is None else y + yt
        return y.reshape(*lead, nb_out * b).astype(x.dtype)

    @jax.custom_vjp
    def f(x, blocks):
        return _fwd_impl(x, blocks)

    def fwd(x, blocks):
        return _fwd_impl(x, blocks), (x, blocks)

    def bwd(res, dy):
        x, blocks = res
        *lead, _ = x.shape
        dyb = dy.astype(x.dtype).reshape(*lead, nb_out, b)
        xb = x.reshape(*lead, nb_in, b)
        # d_x: transposed butterfly gather (no scatter, model dtype)
        d_x = None
        for u in range(w):
            bl = blocks[src_i[:, u], src_t[:, u]]  # (nb_in, b_in, b_out)
            dg = jnp.take(dyb, src_i[:, u], axis=-2)  # (..., nb_in, b_out)
            term = jnp.einsum(
                "...ic,ikc->...ik", dg, bl,
                preferred_element_type=jnp.float32,
            )
            term = term * jnp.asarray(valid[:, u])[:, None]
            d_x = term if d_x is None else d_x + term
        d_x = d_x.reshape(x.shape).astype(x.dtype)
        # d_blocks: per-slot token contraction (same gathers as forward)
        d_blocks = []
        for t in range(r):
            xg = jnp.take(xb, cols[:, t], axis=-2)  # (..., nb_out, b_in)
            db = jnp.einsum(
                "...ik,...ic->ikc", xg, dyb,
                preferred_element_type=jnp.float32,
            )
            d_blocks.append(db)
        d_blocks = jnp.stack(d_blocks, axis=1).astype(blocks.dtype)
        return d_x, d_blocks

    f.defvjp(fwd, bwd)
    return f


def bsr_matmul_custom_vjp(
    x: jax.Array, blocks: jax.Array, cols: np.ndarray
) -> jax.Array:
    """Gather BSR matmul with the scatter-free transposed-gather backward.
    ``cols`` must be a static numpy table."""
    cols = np.asarray(cols, np.int32)
    nb_out, r, b, _ = blocks.shape
    nb_in = x.shape[-1] // b
    f = _bsr_custom_fn(cols.tobytes(), nb_out, r, nb_in, b)
    return f(x, blocks)


# ----------------------------------------------------------------------
# Block-sparse attention
# ----------------------------------------------------------------------


def block_mask_to_dense(
    block_mask: np.ndarray, bq: int, bk: int, sq: int, sk: int, causal: bool
) -> np.ndarray:
    """Expand an (nqb, nkb) boolean block mask to an (sq, sk) element mask."""
    m = np.repeat(np.repeat(block_mask, bq, axis=0), bk, axis=1)[:sq, :sk]
    if causal:
        m = m & (np.arange(sk)[None, :] <= np.arange(sq)[:, None])
    return m


def dense_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array | None = None,
    *,
    causal: bool = False,
    sm_scale: float | None = None,
) -> jax.Array:
    """Plain masked softmax attention. q,k,v: (B, H, S, D); mask (Sq, Sk)."""
    *_, sq, d = q.shape
    sk = k.shape[-2]
    scale = sm_scale if sm_scale is not None else d ** -0.5
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    neg = jnp.finfo(jnp.float32).min
    if mask is not None:
        logits = jnp.where(mask, logits, neg)
    if causal:
        cm = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        logits = jnp.where(cm, logits, neg)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)


def block_sparse_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    block_mask: np.ndarray,
    *,
    block_q: int,
    block_k: int,
    causal: bool = False,
    sm_scale: float | None = None,
) -> jax.Array:
    """Oracle: dense attention under the expanded block mask."""
    sq, sk = q.shape[-2], k.shape[-2]
    m = block_mask_to_dense(block_mask, block_q, block_k, sq, sk, causal)
    return dense_attention_ref(
        q, k, v, jnp.asarray(m), causal=False, sm_scale=sm_scale
    )
