"""Pallas TPU kernel: flat-block-butterfly (BSR) sparse matmul.

Computes ``y = x @ W`` where ``W`` is an ``(n_in, n_out)`` flat block
butterfly matrix stored as nonzero blocks only:

  x      : (B, n_in)              activations
  blocks : (nb_out, r, b, b)      block slot (i, t) maps input block
                                  ``cols[i, t]`` to output block ``i``
  cols   : (nb_out, r) int32      static column-block index table
  y      : (B, nb_out * b)

TPU adaptation of the paper's Triton DSD block-sparse GEMM:

- grid = (B/bm, nb_out, r): the two outer axes are parallel, the nnz-slot
  axis is an arbitrary (sequential) reduction into the revisited output
  block — output lives in VMEM across the ``t`` loop, so partial sums never
  round-trip to HBM.
- the column-index table rides in scalar memory via
  ``PrefetchScalarGridSpec``; the ``x`` BlockSpec's index_map reads it to
  gather the right input block — the block gather *is* the sparsity.
- ``b`` is a multiple of 128 so every ``jnp.dot`` maps onto full MXU tiles;
  accumulation is fp32 (``preferred_element_type``) regardless of the
  parameter dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["bsr_matmul_pallas"]


def _kernel(cols_ref, x_ref, w_ref, o_ref, acc_ref, *, r: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[0, 0], preferred_element_type=jnp.float32
    )

    @pl.when(t == r - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "interpret", "out_dtype")
)
def bsr_matmul_pallas(
    x: jax.Array,
    blocks: jax.Array,
    cols: jax.Array,
    *,
    bm: int = 256,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """``y[:, i*b:(i+1)*b] = sum_t x[:, cols[i,t]*b:...] @ blocks[i, t]``."""
    if x.ndim != 2:
        raise ValueError("x must be (batch, n_in); flatten leading dims first")
    B, n_in = x.shape
    nb_out, r, b, b2 = blocks.shape
    if b != b2:
        raise ValueError("blocks must be square")
    if n_in % b:
        raise ValueError("n_in must be a multiple of the block size")
    bm = min(bm, B)
    if B % bm:
        raise ValueError(f"batch {B} must be a multiple of bm {bm}")
    out_dtype = out_dtype or x.dtype

    grid = (B // bm, nb_out, r)

    def x_map(i, j, t, cols_ref):
        return (i, cols_ref[j, t])

    def w_map(i, j, t, cols_ref):
        del i
        return (j, t, 0, 0)

    def o_map(i, j, t, cols_ref):
        del t
        return (i, j)

    return pl.pallas_call(
        functools.partial(_kernel, r=r),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, b), x_map),
                pl.BlockSpec((1, 1, b, b), w_map),
            ],
            out_specs=pl.BlockSpec((bm, b), o_map),
            scratch_shapes=[pltpu.VMEM((bm, b), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nb_out * b), out_dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(cols, x, blocks)
