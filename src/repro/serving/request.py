"""Request / sequence lifecycle types for the serving engine.

A ``Request`` is what a client submits; a ``SequenceState`` is a request
bound to a cache slot while it is in flight; a ``FinishedRequest`` is the
terminal record handed back by ``Engine.step``/``drain``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.sampling import SamplingParams

__all__ = ["Request", "SequenceState", "FinishedRequest"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (plen,) int32, plen >= 1
    max_new_tokens: int
    eos_id: int | None = None
    # per-request decoding knobs; the default is exact greedy
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams
    )

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("prompt must have at least one token")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.sampling is None:
            self.sampling = SamplingParams()
        if not isinstance(self.sampling, SamplingParams):
            raise TypeError("sampling must be a SamplingParams")


@dataclasses.dataclass
class SequenceState:
    """An admitted request occupying one cache slot."""

    request: Request
    slot: int
    pos: int = 0  # write position of the *next* decode token
    generated: list[int] = dataclasses.field(default_factory=list)
    admit_step: int = 0
    # prompt tokens served from the prefix cache (0 = full prefill)
    prefix_hit_tokens: int = 0

    @property
    def plen(self) -> int:
        return int(self.request.prompt.size)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and bool(self.generated) and (
            self.generated[-1] == eos
        )


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (n_generated,) int32
    finish_reason: str  # "length" | "eos" | "capacity"
    admit_step: int
    finish_step: int
    # prompt tokens the admission served straight from the prefix cache
    # instead of prefilling (mapped shared pages)
    prefix_hit_tokens: int = 0
