"""Request / sequence lifecycle types for the serving engine.

A ``Request`` is what a client submits; a ``SequenceState`` is a request
bound to a cache slot while it is in flight; a ``FinishedRequest`` is the
terminal record handed back by ``Engine.step``/``drain``.

``ScheduleParams`` is the scheduling sibling of ``SamplingParams``: where
sampling knobs shape *what* a request decodes, scheduling knobs shape
*when* — its priority class, its soft latency deadline, and how long it
is willing to wait in the queue before giving up. The engine's admission
loop orders the waiting queue by (priority desc, deadline asc, FCFS) and
may *preempt* (swap out) a running lower-priority sequence to make room
for a higher-priority one (``repro.serving.swap``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.sampling import SamplingParams

__all__ = [
    "Request",
    "ScheduleParams",
    "SequenceState",
    "FinishedRequest",
    "REJECT_TOO_LARGE",
    "REJECT_TIMEOUT",
    "REJECT_SHED",
]

# ``FinishedRequest.reject_reason`` values (``finish_reason ==
# "rejected"``): the request could *never* fit the engine's geometry,
# it waited longer than its ``ScheduleParams.max_queue_wait_s`` allowed,
# or the SLO burn-rate monitor shed it from the queue under overload
# (``EngineConfig(slo=SloConfig(shed=True))``).
REJECT_TOO_LARGE = "too_large"
REJECT_TIMEOUT = "timeout"
REJECT_SHED = "shed"


@dataclasses.dataclass(frozen=True)
class ScheduleParams:
    """Per-request scheduling knobs. Defaults are best-effort FCFS.

    priority: higher admits (and decodes) first; a waiting request may
        preempt a running sequence of *strictly lower* priority when the
        pool is full (``EngineConfig(preemption=...)``).
    deadline_s: soft end-to-end latency target in seconds from submit.
        Orders the queue (earliest-deadline-first within a priority
        class) and defines SLO attainment in the stats/benchmarks; the
        engine never kills a request for missing it.
    max_queue_wait_s: give up if not admitted within this many seconds
        of submission — the request finishes with ``finish_reason
        "rejected"`` / ``reject_reason REJECT_TIMEOUT`` instead of
        waiting forever.
    """

    priority: int = 0
    deadline_s: float | None = None
    max_queue_wait_s: float | None = None

    def __post_init__(self):
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (None disables)")
        if self.max_queue_wait_s is not None and self.max_queue_wait_s < 0:
            raise ValueError(
                "max_queue_wait_s must be >= 0 (None disables)"
            )


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (plen,) int32, plen >= 1
    max_new_tokens: int
    eos_id: int | None = None
    # per-request decoding knobs; the default is exact greedy
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams
    )
    # per-request scheduling knobs; the default is best-effort FCFS
    schedule: ScheduleParams = dataclasses.field(
        default_factory=ScheduleParams
    )
    # wall-clock submission time (time.perf_counter), stamped by
    # Engine.submit: the anchor for queue-wait timeouts, TTFT and
    # deadline attainment
    submit_s: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("prompt must have at least one token")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.sampling is None:
            self.sampling = SamplingParams()
        if not isinstance(self.sampling, SamplingParams):
            raise TypeError("sampling must be a SamplingParams")
        if self.schedule is None:
            self.schedule = ScheduleParams()
        if not isinstance(self.schedule, ScheduleParams):
            raise TypeError("schedule must be a ScheduleParams")


@dataclasses.dataclass
class SequenceState:
    """An admitted request occupying one cache slot."""

    request: Request
    slot: int
    pos: int = 0  # write position of the *next* decode token
    generated: list[int] = dataclasses.field(default_factory=list)
    admit_step: int = 0
    # prompt tokens served from the prefix cache (0 = full prefill)
    prefix_hit_tokens: int = 0
    # times this sequence was swapped out to host memory and resumed
    preemptions: int = 0
    # step of the last admit/resume: preemption hysteresis — a sequence
    # must run ``EngineConfig(preempt_min_steps=)`` steps before it can
    # be victimized (again), so a burst can't thrash swap
    resume_step: int = 0
    # wall-clock time the first token was emitted (TTFT anchor)
    first_token_s: float | None = None

    @property
    def plen(self) -> int:
        return int(self.request.prompt.size)

    @property
    def remaining(self) -> int:
        """Decode tokens this sequence may still emit (victim-selection
        key: preempt the longest-remaining first)."""
        return max(0, self.request.max_new_tokens - len(self.generated))

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and bool(self.generated) and (
            self.generated[-1] == eos
        )


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (n_generated,) int32
    finish_reason: str  # "length" | "eos" | "capacity" | "rejected"
    admit_step: int
    finish_step: int
    # prompt tokens the admission served straight from the prefix cache
    # instead of prefilling (mapped shared pages)
    prefix_hit_tokens: int = 0
    # why a "rejected" request never ran (REJECT_* above); None otherwise
    reject_reason: str | None = None
    # times the sequence was swapped out to host memory and resumed
    preemptions: int = 0
    # wall-clock seconds from submit to first token / to completion
    # (None for rejected requests)
    ttft_s: float | None = None
    e2e_s: float | None = None
    # the request's scheduling knobs, echoed so callers can score SLO
    # attainment (e2e_s <= schedule.deadline_s) without a side table
    schedule: ScheduleParams = dataclasses.field(
        default_factory=ScheduleParams
    )

    @property
    def rejected(self) -> bool:
        return self.finish_reason == "rejected"

    @property
    def slo_met(self) -> bool | None:
        """Did this request meet its soft deadline? None when it had no
        deadline; False for rejected deadline'd requests."""
        if self.schedule.deadline_s is None:
            return None
        if self.rejected or self.e2e_s is None:
            return False
        return self.e2e_s <= self.schedule.deadline_s
