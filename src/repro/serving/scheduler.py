"""Slot-based continuous-batching scheduler.

FCFS admission into a fixed set of cache slots: sequences are admitted the
moment a slot (and its KV pages) frees up and evicted the step they
finish — no full-batch barrier, no recompilation (the decode step is
always shaped (max_slots,), idle slots ride along masked).
"""

from __future__ import annotations

from collections import deque

from repro.serving.request import Request, SequenceState

__all__ = ["Scheduler"]


class Scheduler:
    def __init__(self, max_slots: int):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self.waiting: deque[Request] = deque()
        self.slots: list[SequenceState | None] = [None] * max_slots

    # ---- queue -------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def peek_waiting(self) -> Request | None:
        return self.waiting[0] if self.waiting else None

    # ---- slots -------------------------------------------------------
    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, step: int) -> SequenceState | None:
        """Bind the head-of-queue request to a free slot (None if neither)."""
        slot = self.free_slot()
        if slot is None or not self.waiting:
            return None
        req = self.waiting.popleft()
        state = SequenceState(request=req, slot=slot, admit_step=step)
        self.slots[slot] = state
        return state

    def evict(self, slot: int) -> SequenceState:
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is empty")
        self.slots[slot] = None
        return state

    # ---- views -------------------------------------------------------
    def active(self) -> list[SequenceState]:
        return [s for s in self.slots if s is not None]

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def occupancy(self) -> float:
        return self.num_active / self.max_slots

    @property
    def idle(self) -> bool:
        return not self.waiting and self.num_active == 0
