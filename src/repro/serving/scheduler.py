"""Slot-based continuous-batching scheduler with priority admission.

Admission into a fixed set of cache slots: sequences are admitted the
moment a slot (and its KV pages) frees up and evicted the step they
finish — no full-batch barrier, no recompilation (the decode step is
always shaped (max_slots,), idle slots ride along masked).

The waiting queue is a *priority* queue ordered by ``(priority desc,
absolute deadline asc, uid asc)``: higher-priority requests admit first,
earliest-deadline-first breaks ties within a priority class, and FCFS
(monotone uids) breaks the rest — all-default ``ScheduleParams`` traffic
degenerates to the exact FCFS order the engine always had. A preempted
sequence's request re-enters the same queue (its old uid puts it at the
*front* of its class, so a resumed victim never queue-jumps itself).

``peek_admissible(k)`` exposes a bounded lookahead window so the engine
can batch same-bucket prefills and admit around an oversized
head-of-queue request; ``resume`` re-binds a swapped-out sequence's
preserved ``SequenceState`` to a fresh slot.
"""

from __future__ import annotations

import bisect

from repro.serving.request import Request, SequenceState

__all__ = ["Scheduler"]


def _order_key(req: Request) -> tuple:
    deadline = (
        req.submit_s + req.schedule.deadline_s
        if req.schedule.deadline_s is not None
        else float("inf")
    )
    return (-req.schedule.priority, deadline, req.uid)


class Scheduler:
    def __init__(self, max_slots: int, *, on_event=None):
        """``on_event(kind, request)``: optional queue-lifecycle hook
        (kinds: "submit", "admit", "resume", "remove") — the engine
        binds it to its tracer so queue churn shows up as timeline
        instants. None (the default) costs nothing."""
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self._on_event = on_event
        # kept sorted by _order_key (bisect.insort on submit): index 0 is
        # the highest-priority / most-urgent waiting request
        self.waiting: list[Request] = []
        self.slots: list[SequenceState | None] = [None] * max_slots
        # anti-starvation aging: admission passes that admitted *around*
        # each still-waiting request (keyed by uid; cleared on admit)
        self._skips: dict[int, int] = {}

    # ---- queue -------------------------------------------------------
    def submit(self, req: Request) -> None:
        bisect.insort(self.waiting, req, key=_order_key)
        if self._on_event is not None:
            self._on_event("submit", req)

    def peek_admissible(self, k: int) -> list[Request]:
        """Bounded-lookahead admission window: the first ``min(k,
        len(waiting))`` queued requests in priority order, not popped.
        The engine filters this window by slot/page budget and may admit
        later (smaller) requests past an oversized head-of-queue one.
        ``k`` bounds how many requests each admission pass may consider
        (and thus admit past the head). Starvation is bounded by aging:
        the engine reports each pass's skipped-over requests via
        ``note_skips`` and stops admitting around any request whose
        ``skip_count`` reaches ``EngineConfig(max_skips=)``."""
        if k < 1:
            raise ValueError("lookahead k must be >= 1")
        return self.waiting[: min(k, len(self.waiting))]

    def note_skips(self, reqs: list[Request]) -> None:
        """Record one admission pass that admitted *around* each of
        ``reqs`` (a later request got a slot while they waited)."""
        for req in reqs:
            self._skips[req.uid] = self._skips.get(req.uid, 0) + 1

    def skip_count(self, req: Request) -> int:
        return self._skips.get(req.uid, 0)

    def remove(self, request: Request) -> None:
        """Drop a waiting request (queue-wait timeout / structured
        rejection) without binding it to a slot."""
        self._pop_waiting(request)
        self._skips.pop(request.uid, None)
        if self._on_event is not None:
            self._on_event("remove", request)

    def _pop_waiting(self, request: Request) -> Request:
        # remove by identity: dataclass equality would compare numpy
        # prompt arrays (ambiguous-truth ValueError on lookalikes)
        for i, r in enumerate(self.waiting):
            if r is request:
                del self.waiting[i]
                return r
        raise ValueError("request is not in the waiting queue")

    # ---- slots -------------------------------------------------------
    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    @property
    def num_free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    def admit(
        self, step: int, *, request: Request | None = None
    ) -> SequenceState | None:
        """Bind a waiting request to a free slot (None if neither).

        ``request=None`` takes the head of the queue (highest priority,
        then FCFS); passing a specific request (one returned by
        ``peek_admissible``) removes it from wherever it sits in the
        queue — that's how the engine's lookahead admits around an
        oversized head-of-line request."""
        slot = self.free_slot()
        if slot is None or not self.waiting:
            return None
        if request is None:
            req = self.waiting.pop(0)
        else:
            req = self._pop_waiting(request)
        self._skips.pop(req.uid, None)
        state = SequenceState(request=req, slot=slot, admit_step=step)
        self.slots[slot] = state
        if self._on_event is not None:
            self._on_event("admit", req)
        return state

    def resume(
        self, state: SequenceState, *, request: Request
    ) -> SequenceState | None:
        """Re-bind a swapped-out sequence's preserved state to a free
        slot, removing its re-queued request from the waiting queue.
        The state keeps its progress (pos/generated/admit_step); only
        the slot binding changes. None if no slot is free."""
        slot = self.free_slot()
        if slot is None:
            return None
        self._pop_waiting(request)
        self._skips.pop(request.uid, None)
        state.slot = slot
        self.slots[slot] = state
        if self._on_event is not None:
            self._on_event("resume", request)
        return state

    def evict(self, slot: int) -> SequenceState:
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is empty")
        self.slots[slot] = None
        return state

    # ---- views -------------------------------------------------------
    def active(self) -> list[SequenceState]:
        return [s for s in self.slots if s is not None]

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def occupancy(self) -> float:
        return self.num_active / self.max_slots

    @property
    def idle(self) -> bool:
        return not self.waiting and self.num_active == 0
