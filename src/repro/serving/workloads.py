"""Seeded serving-workload generators and a step-driven replay driver.

The serving benchmarks (and any soak test) need *reproducible* traffic
that actually stresses the scheduler: bursts that oversubscribe the
slots, heavy-tailed decode lengths that pin slots for hundreds of steps,
and multi-turn chat where each turn's prompt extends the last turn's
output. Every generator takes a ``numpy`` Generator — same seed, same
trace, bit-for-bit.

Time is measured in *engine steps*, not seconds: a ``WorkItem`` arrives
at ``arrival_step`` and its soft deadline / queue-wait limit are step
counts. ``replay`` converts them to wall-clock seconds with a measured
``step_s`` (seconds per engine step, calibrated on a warm run) when
attaching ``ScheduleParams`` — so the same trace is meaningful on any
machine, and a calibration pass can run with ``step_s=None`` to warm
every program (including the preemption/swap path) without arming any
deadline.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.request import FinishedRequest, ScheduleParams
from repro.serving.sampling import SamplingParams

__all__ = [
    "WorkItem",
    "poisson_burst",
    "long_tail",
    "chat_turns",
    "replay",
    "replay_chat",
    "goodput",
]


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One request of a generated trace (times in engine steps)."""

    arrival_step: int
    prompt: np.ndarray
    max_new_tokens: int
    priority: int = 0
    deadline_steps: int | None = None
    max_queue_wait_steps: int | None = None
    sampling: SamplingParams | None = None


# ---- generators ------------------------------------------------------
def poisson_burst(
    rng: np.random.Generator,
    *,
    vocab: int,
    page: int,
    n_background: int,
    n_burst: int,
    burst_step: int,
    background_gen: int,
    burst_gen: int,
    deadline_steps: int,
    burst_priority: int = 5,
) -> list[WorkItem]:
    """Steady background load hit by a latency-critical burst.

    ``n_background`` long-decode, no-deadline requests arrive at step 0
    (Poisson-thinned arrival jitter of a step or two) and occupy every
    slot; at ``burst_step`` a burst of ``n_burst`` short, high-priority,
    deadline'd requests lands on the full pool. With preemption the
    burst swaps the background out and meets its deadlines; without it
    the burst queues behind ``background_gen`` decode steps and misses
    them — the benchmark's headline SLO-attainment comparison."""
    items = [
        WorkItem(
            arrival_step=int(rng.poisson(0.5)),
            prompt=rng.integers(
                1, vocab, page + int(rng.integers(4, page // 2))
            ).astype(np.int32),
            max_new_tokens=background_gen,
        )
        for _ in range(n_background)
    ]
    items += [
        WorkItem(
            arrival_step=burst_step + int(rng.poisson(0.5)),
            prompt=rng.integers(
                1, vocab, int(rng.integers(8, page // 2))
            ).astype(np.int32),
            max_new_tokens=burst_gen,
            priority=burst_priority,
            deadline_steps=deadline_steps,
        )
        for _ in range(n_burst)
    ]
    return sorted(items, key=lambda w: w.arrival_step)


def long_tail(
    rng: np.random.Generator,
    *,
    vocab: int,
    page: int,
    n: int,
    mean_gap_steps: float,
    short_gen: tuple[int, int],
    heavy_gen: int,
    heavy_frac: float = 0.2,
    deadline_steps: int | None = None,
) -> list[WorkItem]:
    """Heavy-tailed open-loop traffic: exponential arrival gaps, mostly
    short interactive requests (priority 1, deadline'd) with a
    ``heavy_frac`` tail of long-decode batch requests (priority 0, no
    deadline) that pin slots for ``heavy_gen`` steps each. Preemption
    lets the interactive tier cut through the batch tier."""
    items, t = [], 0.0
    for _ in range(n):
        t += rng.exponential(mean_gap_steps)
        heavy = rng.random() < heavy_frac
        items.append(
            WorkItem(
                arrival_step=int(t),
                prompt=rng.integers(
                    1, vocab, int(rng.integers(page // 4, page))
                ).astype(np.int32),
                max_new_tokens=heavy_gen
                if heavy
                else int(rng.integers(*short_gen)),
                priority=0 if heavy else 1,
                deadline_steps=None if heavy else deadline_steps,
            )
        )
    return items


def chat_turns(
    rng: np.random.Generator,
    *,
    vocab: int,
    n_users: int,
    n_turns: int,
    user_tokens: int,
    gen: int,
) -> list[list[tuple[np.ndarray, int]]]:
    """Multi-turn chat: each conversation is ``n_turns`` of
    ``user_tokens`` new user input answered by ``gen`` tokens. Turn
    ``t``'s prompt is the whole history (previous prompt + previous
    answer + new user text), so with the prefix cache on, turn 2+
    admissions should hit the turn-1 pages — *including the
    decode-written answer pages* the engine indexes at finish."""
    return [
        [
            (
                rng.integers(1, vocab, user_tokens).astype(np.int32),
                gen,
            )
            for _ in range(n_turns)
        ]
        for _ in range(n_users)
    ]


# ---- replay ----------------------------------------------------------
def _schedule(item: WorkItem, step_s: float | None) -> ScheduleParams:
    if step_s is None:  # calibration: priorities live, deadlines unarmed
        return ScheduleParams(priority=item.priority)
    return ScheduleParams(
        priority=item.priority,
        deadline_s=(
            item.deadline_steps * step_s
            if item.deadline_steps is not None
            else None
        ),
        max_queue_wait_s=(
            item.max_queue_wait_steps * step_s
            if item.max_queue_wait_steps is not None
            else None
        ),
    )


def replay(
    engine, items: list[WorkItem], *, step_s: float | None
) -> tuple[list[FinishedRequest], float, int]:
    """Drive one trace through the engine: submit each item the step it
    arrives, stepping until everything finishes. Returns (finished,
    wall seconds, steps). ``step_s`` converts step-denominated deadlines
    to wall-clock ``ScheduleParams``; ``None`` leaves deadlines unarmed
    (calibration/warm runs — preemption still fires on priority)."""
    import time

    items = sorted(items, key=lambda w: w.arrival_step)
    fins: list[FinishedRequest] = []
    i, step = 0, 0
    t0 = time.perf_counter()
    while i < len(items) or not engine.scheduler.idle or engine._rejected:
        while i < len(items) and items[i].arrival_step <= step:
            engine.submit(
                items[i].prompt,
                items[i].max_new_tokens,
                sampling=items[i].sampling,
                schedule=_schedule(items[i], step_s),
            )
            i += 1
        fins.extend(engine.step())
        step += 1
    return fins, time.perf_counter() - t0, step


def replay_chat(
    engine, convs: list[list[tuple[np.ndarray, int]]]
) -> tuple[dict[int, list[FinishedRequest]], float, int]:
    """Drive multi-turn conversations: every conversation's next turn is
    submitted the step its previous turn finishes, with the full history
    as the prompt. Returns (finished by turn index, wall s, steps)."""
    import time

    active: dict[int, tuple[int, int, np.ndarray]] = {}
    by_turn: dict[int, list[FinishedRequest]] = {}
    for ci, conv in enumerate(convs):
        user, gen = conv[0]
        uid = engine.submit(user, gen)
        active[uid] = (ci, 0, user)
    step = 0
    t0 = time.perf_counter()
    while active or not engine.scheduler.idle:
        for f in engine.step():
            ci, ti, prompt = active.pop(f.uid)
            by_turn.setdefault(ti, []).append(f)
            if ti + 1 < len(convs[ci]):
                user, gen = convs[ci][ti + 1]
                nxt = np.concatenate([prompt, f.tokens, user])
                uid = engine.submit(nxt, gen)
                active[uid] = (ci, ti + 1, nxt)
        step += 1
    return by_turn, time.perf_counter() - t0, step


# ---- folding ---------------------------------------------------------
def _pct(vals: list[float], q: float) -> float:
    return (
        round(float(np.percentile(np.asarray(vals), q)) * 1e3, 3)
        if vals
        else 0.0
    )


def goodput(fins: list[FinishedRequest], stats: dict) -> dict:
    """Fold one replay into the benchmark's goodput row: SLO attainment
    over deadline'd requests, TTFT percentiles, preemption/swap volume,
    and rejections. ``stats`` is the engine's ``stats_summary()`` for
    the same run (per-token latency + swap byte counters)."""
    dl = [f for f in fins if f.schedule.deadline_s is not None]
    met = sum(1 for f in dl if f.slo_met)
    ttft = [f.ttft_s for f in fins if f.ttft_s is not None]
    pre = stats["preemption"]
    return {
        "requests": len(fins),
        "with_deadline": len(dl),
        "slo_met": met,
        "slo_attainment": round(met / len(dl), 4) if dl else 1.0,
        "ttft_p50_ms": _pct(ttft, 50),
        "ttft_p95_ms": _pct(ttft, 95),
        "ttft_p99_ms": _pct(ttft, 99),
        "p50_token_latency_ms": stats["p50_token_latency_ms"],
        "p99_token_latency_ms": stats["p99_token_latency_ms"],
        "preemptions": pre["preemptions"],
        "resumes": pre["resumes"],
        "swap_out_bytes": pre.get("out_bytes", 0),
        "swap_in_bytes": pre.get("in_bytes", 0),
        "rejected": stats["rejected"]["total"],
    }
