"""Block-paged KV cache: page size == the attention block size.

The pixelfly attention pattern is block-structured (local + butterfly
strides + global cross, ``repro.core.attn_pattern``), so sizing cache
pages in units of ``cfg.attn_block`` makes the sparse decode schedule a
*page-id* computation: each token gathers only the O(b·log n) pages its
schedule visits, never the whole cache.

Device state lives in ``buffers`` (one pool per layer group, built by
``transformer.init_paged_cache``); the page table and free list are tiny
host-side numpy/python structures updated between jit'd steps. Physical
page 0 is the shared trash page: idle slots and unallocated table entries
point at it, and every read masks it out via logical positions.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

__all__ = ["PagedKVCache"]


class PagedKVCache:
    def __init__(
        self,
        cfg: ModelConfig,
        max_slots: int,
        max_len: int,
        *,
        n_pages: int = 0,
    ):
        """``n_pages=0`` sizes the pool worst-case (every slot full).
        A smaller pool *oversubscribes* the cache — the engine budgets
        each sequence's lifetime pages (prompt + decode growth, capped at
        ``max_new_tokens``) at admission, so more sequences fit than the
        worst case without ``alloc_upto`` ever running dry mid-decode."""
        page = cfg.attn_block
        if max_len % page:
            raise ValueError(
                f"max_len {max_len} must be a multiple of the page size "
                f"(attn_block={page})"
            )
        self.cfg = cfg
        self.page = page
        self.max_slots = max_slots
        self.pages_per_seq = max_len // page
        self.max_len = max_len
        # worst case every slot is full, +1 for the trash page
        worst = max_slots * self.pages_per_seq + 1
        self.n_pages = n_pages or worst
        if not self.pages_per_seq + 1 <= self.n_pages <= worst:
            raise ValueError(
                f"n_pages {self.n_pages} must be in "
                f"[{self.pages_per_seq + 1}, {worst}] (one full slot + "
                "trash .. every slot full + trash)"
            )
        self.buffers = T.init_paged_cache(cfg, self.n_pages, page)
        self.page_table = np.zeros(
            (max_slots, self.pages_per_seq), np.int32
        )
        self._free: list[int] = list(range(self.n_pages - 1, 0, -1))
        self._owned: dict[int, list[int]] = {}

    # ---- allocation --------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for_len(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page)

    def pages_owned(self, slot: int) -> int:
        return len(self._owned.get(slot, []))

    def alloc_upto(self, slot: int, pos: int) -> None:
        """Ensure logical pages [0, pos // page] of ``slot`` are backed."""
        need = pos // self.page + 1
        if need > self.pages_per_seq:
            raise ValueError(
                f"position {pos} exceeds slot capacity {self.max_len}"
            )
        owned = self._owned.setdefault(slot, [])
        while len(owned) < need:
            if not self._free:
                raise RuntimeError("KV cache out of pages")
            p = self._free.pop()
            self.page_table[slot, len(owned)] = p
            owned.append(p)

    def free_slot(self, slot: int) -> None:
        for p in self._owned.pop(slot, []):
            self._free.append(p)
        self.page_table[slot, :] = 0

    # ---- views -------------------------------------------------------
    def table_row(self, slot: int, n_pages: int) -> np.ndarray:
        return self.page_table[slot, :n_pages].copy()

    def bucket_row(self, slot: int, plen: int, n_pages: int) -> np.ndarray:
        """Prefill page row for a bucket of ``n_pages``: the slot's
        ``pages_for_len(plen)`` allocated pages followed by trash-page
        zeros — page allocation is trimmed to the real prompt, and the
        bucket-padding keys scatter to the trash page (which every read
        masks by logical position)."""
        need = self.pages_for_len(plen)
        if need > n_pages:
            raise ValueError(
                f"prompt of {plen} tokens needs {need} pages, bucket has "
                f"{n_pages}"
            )
        row = np.zeros(n_pages, np.int32)
        row[:need] = self.page_table[slot, :need]
        return row

    def memory_bytes(self) -> int:
        return sum(
            int(np.prod(b.shape)) * b.dtype.itemsize
            for pool in self.buffers
            for b in pool.values()
        )
