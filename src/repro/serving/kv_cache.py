"""Block-paged KV cache: page size == the attention block size.

The pixelfly attention pattern is block-structured (local + butterfly
strides + global cross, ``repro.core.attn_pattern``), so sizing cache
pages in units of ``cfg.attn_block`` makes the sparse decode schedule a
*page-id* computation: each token gathers only the O(b·log n) pages its
schedule visits, never the whole cache.

Device state lives in ``buffers`` (one pool per layer group, built by
``transformer.init_paged_cache``); the page table and free list are tiny
host-side numpy/python structures updated between jit'd steps. Physical
page 0 is the shared trash page: idle slots and unallocated table entries
point at it, and every read masks it out via logical positions.

Pages are *refcounted* so the prefix cache (``repro.serving.prefix``) can
map one physical page into several slots' tables — both the paged decode
kernels and ``prefill_paged`` read KV through page-table indirection, so
physically shared pages cost nothing at read time. Every page is in
exactly one of three states:

  free    on ``_free`` (refcount 0) — allocatable;
  live    refcount >= 1 — mapped into that many slots (or transiently
          *pinned* by an admission plan, see ``incref``/``unpin``);
  parked  refcount 0 but kept in ``_cached`` — content still indexed by
          the prefix cache, reusable by a future hit, evictable back to
          the free list at any time (``release_cached``).

A slot must never write into a page it does not exclusively own:
``cow_page`` gives it a fresh page with a jit'd device-side copy of the
shared one (copy-on-write).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding as sharding_lib
from repro.models import transformer as T

__all__ = ["PagedKVCache"]


def _copy_page_impl(buffers, src: jax.Array, dst: jax.Array, *, shardings):
    """Device-side page copy across every layer pool (COW split)."""
    out = jax.tree.map(lambda b: b.at[:, dst].set(b[:, src]), buffers)
    return sharding_lib.constrain_pools(out, shardings)


class PagedKVCache:
    def __init__(
        self,
        cfg: ModelConfig,
        max_slots: int,
        max_len: int,
        *,
        n_pages: int = 0,
        strategy: "sharding_lib.Strategy | None" = None,
    ):
        """``n_pages=0`` sizes the pool worst-case (every slot full).
        A smaller pool *oversubscribes* the cache — the engine budgets
        each sequence's lifetime pages (prompt + decode growth, capped at
        ``max_new_tokens``) at admission, so more sequences fit than the
        worst case without ``alloc_upto`` ever running dry mid-decode.

        ``strategy`` shards the pools across its mesh
        (``sharding.cache_specs(layout="paged")``: one head axis on the
        model axis, page axes replicated) so one engine spans a
        tensor-parallel device mesh. The host-side page table, free list
        and refcounts are unchanged — paging is device-layout-agnostic
        because the page axes are never sharded."""
        page = cfg.attn_block
        if max_len % page:
            raise ValueError(
                f"max_len {max_len} must be a multiple of the page size "
                f"(attn_block={page})"
            )
        self.cfg = cfg
        self.page = page
        self.max_slots = max_slots
        self.pages_per_seq = max_len // page
        self.max_len = max_len
        # worst case every slot is full, +1 for the trash page
        worst = max_slots * self.pages_per_seq + 1
        self.n_pages = n_pages or worst
        if not self.pages_per_seq + 1 <= self.n_pages <= worst:
            raise ValueError(
                f"n_pages {self.n_pages} must be in "
                f"[{self.pages_per_seq + 1}, {worst}] (one full slot + "
                "trash .. every slot full + trash)"
            )
        self.strategy = strategy
        self.shardings = None
        if strategy is not None and strategy.mesh.size > 1:
            shapes = jax.eval_shape(
                lambda: T.init_paged_cache(cfg, self.n_pages, page)
            )
            self.shardings = sharding_lib.named(
                strategy,
                sharding_lib.cache_specs(strategy, shapes, layout="paged"),
            )
        self.buffers = T.init_paged_cache(
            cfg, self.n_pages, page, shardings=self.shardings
        )
        # COW page copy, jit'd per cache so the sharded-pool layout pin
        # (constrain_pools, jaxlint JL005) closes over this pool's
        # shardings; single-device caches close over None (no-op).
        self._copy_page = jax.jit(
            functools.partial(_copy_page_impl, shardings=self.shardings),
            donate_argnums=(0,),
        )
        self.page_table = np.zeros(
            (max_slots, self.pages_per_seq), np.int32
        )
        # Device mirror of the page table, uploaded lazily and cached
        # until a table mutation invalidates it: steady-state decode
        # (no admissions, no page-boundary crossings) re-dispatches the
        # same device array instead of paying a (slots, pages) host
        # upload every step.
        self._table_dev: jax.Array | None = None
        self._free: list[int] = list(range(self.n_pages - 1, 0, -1))
        self._owned: dict[int, list[int]] = {}
        # slot references per physical page; the trash page is never
        # refcounted and never leaves index 0
        self._ref = np.zeros((self.n_pages,), np.int32)
        # parked pages: refcount 0, content still indexed by the prefix
        # cache — out of the free list but reclaimable at any time
        self._cached: set[int] = set()

    # ---- allocation --------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Parked (refcount-0, prefix-cache-indexed) pages."""
        return len(self._cached)

    def pages_for_len(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page)

    def pages_owned(self, slot: int) -> int:
        return len(self._owned.get(slot, []))

    def owned_pages(self, slot: int) -> list[int]:
        """The slot's physical pages in logical order (a copy — the
        swap manager snapshots this before freeing the slot)."""
        return list(self._owned.get(slot, []))

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def is_cached(self, page: int) -> bool:
        return page in self._cached

    def alloc_upto(self, slot: int, pos: int) -> None:
        """Ensure logical pages [0, pos // page] of ``slot`` are backed.

        Atomic: on pool exhaustion every page this call allocated is
        rolled back before raising, so ``_owned``/``page_table`` are
        never left half-grown (the engine treats the raise as "request
        cannot proceed", not "cache corrupted")."""
        need = pos // self.page + 1
        if need > self.pages_per_seq:
            raise ValueError(
                f"position {pos} exceeds slot capacity {self.max_len}"
            )
        owned = self._owned.setdefault(slot, [])
        if len(owned) < need:
            self._table_dev = None  # growing (or rolling back) the table
        added: list[int] = []
        while len(owned) < need:
            if not self._free:
                for p in reversed(added):
                    owned.pop()
                    self.page_table[slot, len(owned)] = 0
                    self._ref[p] = 0
                    self._free.append(p)
                if not owned:
                    del self._owned[slot]
                raise RuntimeError("KV cache out of pages")
            p = self._free.pop()
            self._ref[p] = 1
            self.page_table[slot, len(owned)] = p
            owned.append(p)
            added.append(p)

    def free_slot(
        self, slot: int, *, keep: Callable[[int], bool] | None = None
    ) -> None:
        """Drop the slot's references. A page whose refcount hits zero
        returns to the free list — unless ``keep(page)`` claims it, in
        which case it is *parked* (kept device-resident for the prefix
        cache, reclaimable via ``release_cached``)."""
        for p in self._owned.pop(slot, []):
            self._ref[p] -= 1
            if self._ref[p] == 0:
                if keep is not None and keep(p):
                    self._cached.add(p)
                else:
                    self._free.append(p)
        self.page_table[slot, :] = 0
        self._table_dev = None

    # ---- sharing (prefix cache) --------------------------------------
    def incref(self, page: int) -> None:
        """Pin a live page (one more reference, no slot mapping yet)."""
        if page == 0 or self._ref[page] < 1:
            raise ValueError(f"page {page} is not live (cannot incref)")
        self._ref[page] += 1

    def take_cached(self, page: int) -> None:
        """Pin a parked page: refcount 0 -> 1, out of the parked set.
        The caller must ``adopt`` it into a slot or ``unpin`` it."""
        self._cached.remove(page)
        self._ref[page] = 1

    def unpin(self, page: int) -> None:
        """Drop a pin taken by ``incref``/``take_cached`` without a slot
        mapping (an admission plan that was abandoned). A pin dropping to
        refcount 0 parks the page again — pins only ever come from
        prefix-cache-indexed pages."""
        if self._ref[page] < 1:
            raise ValueError(f"page {page} has no reference to unpin")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._cached.add(page)

    def adopt(self, slot: int, pages: list[int]) -> None:
        """Map already-pinned pages as the slot's first logical pages.
        Refcounts are unchanged — each pin becomes the slot's reference.
        Must run before any ``alloc_upto`` on the slot."""
        owned = self._owned.setdefault(slot, [])
        if owned:
            raise ValueError(f"slot {slot} already owns pages")
        for i, p in enumerate(pages):
            self.page_table[slot, i] = p
            owned.append(int(p))
        self._table_dev = None

    def release_cached(self, page: int) -> None:
        """Evict a parked page back to the free list (LRU eviction by
        the prefix cache — its index entry must go too)."""
        self._cached.remove(page)
        self._free.append(page)

    def cow_page(
        self,
        slot: int,
        logical: int,
        *,
        keep: Callable[[int], bool] | None = None,
    ) -> int:
        """Copy-on-write: give ``slot`` a private copy of its logical
        page ``logical`` (a fresh page + a jit'd device-side copy of the
        shared page's contents), dropping its reference on the shared
        one. Returns the new physical page. ``keep`` follows
        ``free_slot`` semantics if the source refcount hits zero."""
        owned = self._owned[slot]
        src = owned[logical]
        if not self._free:
            raise RuntimeError("KV cache out of pages")
        dst = self._free.pop()
        self._ref[dst] = 1
        self.buffers = self._copy_page(
            self.buffers,
            jnp.asarray(src, jnp.int32),
            jnp.asarray(dst, jnp.int32),
        )
        self._ref[src] -= 1
        if self._ref[src] == 0:
            if keep is not None and keep(src):
                self._cached.add(src)
            else:
                self._free.append(src)
        owned[logical] = dst
        self.page_table[slot, logical] = dst
        self._table_dev = None
        return dst

    # ---- views -------------------------------------------------------
    def device_table(self) -> jax.Array:
        """The full page table as a device array, cached across steps.

        Any table mutation (alloc/free/adopt/COW) invalidates the cache;
        between mutations the decode loop re-dispatches the same
        committed array, so a steady state whose sequences sit inside a
        page pays zero host→device uploads for the table."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.page_table)
        return self._table_dev

    def table_row(self, slot: int, n_pages: int) -> np.ndarray:
        return self.page_table[slot, :n_pages].copy()

    def bucket_row(self, slot: int, plen: int, n_pages: int) -> np.ndarray:
        """Prefill page row for a bucket of ``n_pages``: the slot's
        ``pages_for_len(plen)`` allocated pages followed by trash-page
        zeros — page allocation is trimmed to the real prompt, and the
        bucket-padding keys scatter to the trash page (which every read
        masks by logical position)."""
        need = self.pages_for_len(plen)
        if need > n_pages:
            raise ValueError(
                f"prompt of {plen} tokens needs {need} pages, bucket has "
                f"{n_pages}"
            )
        row = np.zeros(n_pages, np.int32)
        row[:need] = self.page_table[slot, :need]
        return row

    def suffix_row(
        self, slot: int, n_prefix_pages: int, plen: int, n_pages: int
    ) -> np.ndarray:
        """Prefill page row for the *uncached suffix* of a prefix-cache
        hit: the slot's logical pages [n_prefix_pages,
        pages_for_len(plen)) followed by trash zeros. The suffix is
        page-aligned by construction (prefix hits cover full pages), so
        suffix token i scatters into row entry i // page."""
        need = self.pages_for_len(plen) - n_prefix_pages
        if need > n_pages:
            raise ValueError(
                f"suffix needs {need} pages, bucket has {n_pages}"
            )
        row = np.zeros(n_pages, np.int32)
        row[:need] = self.page_table[
            slot, n_prefix_pages : n_prefix_pages + need
        ]
        return row

    def memory_bytes(self) -> int:
        return sum(
            int(np.prod(b.shape)) * b.dtype.itemsize
            for pool in self.buffers
            for b in pool.values()
        )
