"""Prefix cache: refcounted radix-tree page sharing over the paged pool.

Most serving traffic shares long prompt prefixes (system prompts,
few-shot templates, chat history). Pages are sized to ``attn_block`` and
every paged read goes through page-table indirection, so two slots can
point at the *same* physical page for free — the tree below is the
matcher/allocator that makes that safe:

- **Keying**: a trie over full token *blocks* (one node per cache page).
  A node's edge key is the raw bytes of its ``page``-token block, so a
  path from the root spells a prompt prefix in page units and maps it to
  the physical pages that already hold its K/V. Chained block keys make
  this exactly the "per-page token-block hash" radix keying: matching is
  one dict hop per page, no token-level scan.
- **Lifetime**: the tree holds *no* references. A node's page is either
  live (mapped into >= 1 slots, ``kv.refcount > 0``) or *parked*
  (refcount 0, kept in ``kv._cached``). Parked pages are an opportunistic
  use of free pool space: ``ensure_free`` evicts least-recently-used
  parked *leaves* back to the free list whenever the allocator needs
  pages, so caching never blocks admission. (A parked node's descendants
  are always parked too — a live child's slot would hold the whole
  path — so LRU leaf eviction always makes progress.)
- **Insertion** registers a request's full prompt blocks after its
  prefill completes (never before: two identical prompts admitted in the
  same jit'd wave must not read pages the same program is still
  writing). A block that is already indexed keeps its existing page; the
  newcomer's duplicate page simply stays private to its slot and is
  freed, not parked, when the slot dies.

The matcher caps a hit at ``plen - 1`` tokens so at least one suffix
token is always prefilled — the last prompt token's logits are what emit
the first output token.
"""

from __future__ import annotations

import heapq

import numpy as np

from repro.obs import MetricsRegistry
from repro.serving.kv_cache import PagedKVCache

__all__ = ["PrefixCache", "PrefixStats"]


class _Node:
    __slots__ = ("page", "parent", "key", "children", "tick")

    def __init__(self, page: int, parent: "_Node | None", key: bytes):
        self.page = page
        self.parent = parent
        self.key = key  # this node's edge key in parent.children
        self.children: dict[bytes, _Node] = {}
        self.tick = 0


class PrefixStats:
    """Tree-side counters the engine folds into ``ServeStats`` (which
    tracks the per-admission hit numbers itself)."""

    def __init__(self, registry: MetricsRegistry | None = None):
        reg = registry if registry is not None else MetricsRegistry()
        self.registry = reg
        self._inserted = reg.counter(
            "repro_prefix_inserted_pages_total",
            "pages indexed into the radix tree",
        )
        self._evicted = reg.counter(
            "repro_prefix_evicted_pages_total",
            "radix-indexed pages evicted",
        )
        # live tree size; the engine refreshes it from the kv pool
        # before export (Engine.metrics())
        self._cached = reg.gauge(
            "repro_prefix_cached_pages", "pages currently radix-indexed"
        )

    inserted_pages = property(lambda self: self._inserted.value)
    evicted_pages = property(lambda self: self._evicted.value)

    def record_inserted(self, n: int) -> None:
        self._inserted.inc(n)

    def record_evicted(self, n: int = 1) -> None:
        self._evicted.inc(n)

    def set_cached_pages(self, n: int) -> None:
        self._cached.set(n)

    def snapshot(self) -> dict:
        return {
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
        }


class PrefixCache:
    def __init__(
        self, kv: PagedKVCache, *, metrics: MetricsRegistry | None = None
    ):
        self.kv = kv
        self._root = _Node(page=-1, parent=None, key=b"")
        self._by_page: dict[int, _Node] = {}
        self._tick = 0
        self.stats = PrefixStats(metrics)

    # ---- keying ------------------------------------------------------
    def _block_key(self, prompt: np.ndarray, i: int) -> bytes:
        page = self.kv.page
        return np.ascontiguousarray(
            prompt[i * page : (i + 1) * page], dtype=np.int32
        ).tobytes()

    @property
    def nodes(self) -> int:
        return len(self._by_page)

    def page_in_tree(self, page: int) -> bool:
        """The ``keep`` hook for ``kv.free_slot``/``kv.cow_page``: a
        zero-ref page the tree still indexes is parked, not freed."""
        return page in self._by_page

    # ---- matching ----------------------------------------------------
    def match(self, prompt: np.ndarray) -> list[int]:
        """Longest indexed full-block prefix of ``prompt`` -> physical
        pages, LRU-touched. Capped at ``plen - 1`` tokens so at least
        one suffix token remains to prefill (its logits emit the first
        output token)."""
        n_full = (len(prompt) - 1) // self.kv.page
        node, pages = self._root, []
        for i in range(n_full):
            child = node.children.get(self._block_key(prompt, i))
            if child is None:
                break
            node = child
            pages.append(child.page)
        self._tick += 1
        while node is not self._root:  # refresh the whole hit path
            node.tick = self._tick
            node = node.parent
        return pages

    # ---- insertion ---------------------------------------------------
    def insert(self, prompt: np.ndarray, pages: np.ndarray) -> int:
        """Index the prompt's full token blocks under their physical
        ``pages`` (the slot's page-table row). Existing nodes keep their
        mapping — a duplicate page stays private to its slot. Returns
        the number of newly indexed pages."""
        n_full = len(prompt) // self.kv.page
        self._tick += 1
        node, new = self._root, 0
        for i in range(n_full):
            key = self._block_key(prompt, i)
            child = node.children.get(key)
            if child is None:
                child = _Node(page=int(pages[i]), parent=node, key=key)
                node.children[key] = child
                self._by_page[child.page] = child
                new += 1
            child.tick = self._tick
            node = child
        self.stats.record_inserted(new)
        return new

    # ---- eviction ----------------------------------------------------
    def evictable_pages(self) -> int:
        """Parked pages are reclaimable at any time: the admission
        budget may count them as free."""
        return self.kv.cached_pages

    def ensure_free(self, n: int) -> bool:
        """Evict LRU parked leaves until the free list holds ``n`` pages
        (True) or nothing evictable remains (False). The allocator calls
        this before growing a slot, so parked pages never block it.

        One pass collects the parked leaves into a tick-ordered heap; a
        dropped leaf may turn its parent into a fresh parked leaf, which
        is pushed as it appears — reclaiming k pages costs
        O(parked + k log parked), not k rescans of the parked set."""
        if self.kv.free_pages >= n:
            return True
        heap = [
            (node.tick, page)
            for page in self.kv._cached
            if (node := self._by_page.get(page)) is not None
            and not node.children
        ]
        heapq.heapify(heap)
        while self.kv.free_pages < n:
            if not heap:
                return False
            _, page = heapq.heappop(heap)
            node = self._by_page[page]
            parent = node.parent
            self._drop(node)
            if (
                parent is not self._root
                and not parent.children
                and self.kv.is_cached(parent.page)
            ):
                heapq.heappush(heap, (parent.tick, parent.page))
        return True

    def _drop(self, node: _Node) -> None:
        assert not node.children
        del node.parent.children[node.key]
        del self._by_page[node.page]
        self.kv.release_cached(node.page)
        self.stats.record_evicted(1)
