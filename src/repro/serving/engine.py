"""Continuous-batching serving engine over the block-paged KV cache.

``Engine.submit()`` enqueues requests; each ``step()`` drains the waiting
queue in one admission pass — the first ``lookahead`` queued requests are
grouped by prefill bucket and each same-bucket group is admitted with ONE
jit'd batched prefill call and ONE host sync (no per-request prefill
loop, and an oversized head-of-queue request no longer blocks smaller
ones behind it) — then runs ONE jit'd decode step over all slots (ragged
per-slot positions, idle slots masked to the trash page), and evicts
finished sequences so their slot and pages are reusable the very next
step. ``drain()`` loops until the queue and slots are empty.

The decode step is always shaped ``(max_slots,)`` and prefill shapes are
bucketed to power-of-two page counts *and* power-of-two batch sizes
(groups split greedily into exact power-of-two chunks, so every call
fills its compiled program), so the engine compiles a handful of programs
total no matter how ragged the traffic is. Page allocation is trimmed to
the real prompt length — bucket padding never pins real pages.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import transformer as T
from repro.serving import sampling as sampling_lib
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix import PrefixCache, PrefixStats
from repro.serving.request import FinishedRequest, Request, SequenceState
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler
from repro.serving.stats import ServeStats

__all__ = ["Engine", "EngineConfig"]


class EngineConfig:
    """Serving knobs: ``max_slots`` concurrent sequences, each with
    ``max_len`` tokens of page-granular KV capacity. ``lookahead`` bounds
    how many waiting requests one admission pass may inspect (default
    ``2 * max_slots``): within that window smaller requests may be
    admitted past an oversized head-of-queue one. ``max_prefill_batch``
    caps how many same-bucket requests share one jit'd prefill call
    (0 -> ``max_slots``; 1 reproduces per-request admission, kept as the
    benchmark baseline). ``max_skips`` bounds starvation: a waiting
    request that ``max_skips`` admission passes have admitted *around*
    (lookahead picked later, smaller requests over it) becomes a
    barrier — nothing behind it is admitted until it fits (0 disables
    aging). ``prefix_cache`` turns on radix-tree prefix reuse: admission
    maps cached prompt-prefix pages straight into the new slot's page
    table and prefills only the uncached suffix
    (``repro.serving.prefix``)."""

    def __init__(
        self,
        max_slots: int = 8,
        max_len: int = 512,
        *,
        lookahead: int | None = None,
        max_prefill_batch: int = 0,
        n_pages: int = 0,
        sampler_candidates: int = 64,
        max_skips: int = 64,
        prefix_cache: bool = False,
    ):
        self.max_slots = max_slots
        self.max_len = max_len
        self.n_pages = n_pages  # 0 -> worst-case pool (see PagedKVCache)
        self.lookahead = (
            lookahead if lookahead is not None else 2 * max_slots
        )
        if self.lookahead < 1:
            raise ValueError("lookahead must be >= 1")
        if max_skips < 0:
            raise ValueError("max_skips must be >= 0 (0 disables aging)")
        self.max_skips = max_skips
        self.prefix_cache = prefix_cache
        self.max_prefill_batch = max_prefill_batch or max_slots
        if not 1 <= self.max_prefill_batch <= max_slots:
            raise ValueError(
                f"max_prefill_batch {self.max_prefill_batch} must be in "
                f"[1, max_slots={max_slots}]"
            )
        # static candidate cap for the fused sampler: the sampled branch
        # draws from the top-C logits (lax.top_k, O(V log C)) instead of
        # full-vocab sorting (O(V log V) — ~100ms/step at 50k vocab).
        # Requests may not ask for top_k beyond it (Engine.submit
        # raises). 0 -> uncapped exact full-vocab semantics.
        self.sampler_candidates = sampler_candidates or None
        if sampler_candidates < 0:
            raise ValueError("sampler_candidates must be >= 0")

    def rounded(self, page: int) -> "EngineConfig":
        max_len = -(-self.max_len // page) * page
        return EngineConfig(
            self.max_slots,
            max_len,
            lookahead=self.lookahead,
            max_prefill_batch=self.max_prefill_batch,
            n_pages=self.n_pages,
            sampler_candidates=self.sampler_candidates or 0,
            max_skips=self.max_skips,
            prefix_cache=self.prefix_cache,
        )


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 1 else 1


def _argmax_first(out):
    """(logits, *rest) -> (argmax token ids, *rest): fuses the greedy
    pick into the plain jit variants so they, too, sync token ids only."""
    logits, *rest = out
    return (jnp.argmax(logits, axis=-1).astype(jnp.int32), *rest)


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        *,
        engine_cfg: EngineConfig | None = None,
        strategy: str = "fsdp",
        seed: int = 0,
        params=None,
        paged_impl: str | None = None,
    ):
        """``paged_impl`` selects the paged decode-attention read:
        "gather" (portable jnp reference), "pallas" (fused page-pool
        TPU kernel), or "interpret" (the kernel body interpreted, for
        validation). None picks per platform like ``kernels.ops``."""
        self.mesh = mesh
        st = sharding.Strategy(mesh, strategy)
        self.cfg = cfg = cfg.replace(tp_size=st.tp_size, batch_axes=st.batch)
        self.st = st
        ecfg = (engine_cfg or EngineConfig()).rounded(cfg.attn_block)
        self.ecfg = ecfg
        with mesh:
            if params is None:
                key = jax.random.PRNGKey(seed)
                pshape = jax.eval_shape(lambda k: T.init_model(k, cfg), key)
                psh = sharding.param_shardings(st, pshape)
                params = jax.jit(
                    lambda k: T.init_model(k, cfg), out_shardings=psh
                )(key)
            self.params = params
            self.kv = PagedKVCache(
                cfg, ecfg.max_slots, ecfg.max_len, n_pages=ecfg.n_pages
            )
            if paged_impl is None:
                from repro.kernels.ops import default_impl

                paged_impl = (
                    "pallas" if default_impl() == "pallas" else "gather"
                )
            if paged_impl not in ("gather", "pallas", "interpret"):
                raise ValueError(
                    f"unknown paged_impl {paged_impl!r}; expected "
                    "'gather', 'pallas' or 'interpret'"
                )
            self.paged_impl = paged_impl
            # Slot-indexed sampling state. The host-side (slots,) param
            # rows are written at admission; each step packs them into
            # device arrays so the sampler runs INSIDE the jit'd step —
            # the jit returns token ids, and sampled decode keeps the
            # greedy baseline's single host sync per step. ``presence``
            # ((slots, V+1) bool, col V absorbs padding) tracks each
            # slot's prompt+generated tokens for the repetition penalty
            # and stays device-resident, threaded through both jits.
            ms = ecfg.max_slots
            self._samp = {
                "temp": np.zeros((ms,), np.float32),
                "top_k": np.zeros((ms,), np.int32),
                "top_p": np.ones((ms,), np.float32),
                "rep": np.ones((ms,), np.float32),
                "key": np.zeros((ms, 2), np.uint32),
            }
            # device copy of the packed rows; params change only at
            # admission, so steady-state sampled decode re-uses the
            # cached arrays instead of re-transferring 5 arrays a step
            self._samp_dev: dict | None = None
            self._presence = jnp.zeros(
                (ms, cfg.padded_vocab + 1), jnp.bool_
            )
            # Two compiled variants per step kind. The *plain* variant
            # (in-jit argmax, no sampler state — greedy traffic's fast
            # path, zero sampling overhead) serves steps where no active
            # request needs noise or the presence buffer; the *sampled*
            # variant fuses the full sampler. Both decode variants are
            # warmed at init so neither compiles mid-traffic. Presence
            # rides as its own (donatable) arg; the small (slots,) param
            # arrays are re-packed from host each call.
            self._decode = jax.jit(
                lambda p, c, t, pos, pt: _argmax_first(
                    T.decode_step_paged(
                        cfg, p, c, t, pos, pt, paged_impl=paged_impl
                    )
                ),
                donate_argnums=(1,),
            )
            self._decode_sampled = jax.jit(
                lambda p, c, t, pos, pt, samp, pres: T.decode_step_paged(
                    cfg, p, c, t, pos, pt, paged_impl=paged_impl,
                    sampler={**samp, "presence": pres},
                    sampler_candidates=ecfg.sampler_candidates,
                ),
                donate_argnums=(1, 6),
            )
            # one wrapper; jax.jit specializes per (N, S) bucket shape
            self._prefill = jax.jit(
                lambda p, t, plens, c, rows: _argmax_first(
                    T.prefill_paged(cfg, p, t, plens, c, rows)
                ),
                donate_argnums=(3,),
            )
            self._prefill_sampled = jax.jit(
                lambda p, t, plens, c, rows, samp, pres: T.prefill_paged(
                    cfg, p, t, plens, c, rows,
                    sampler={**samp, "presence": pres},
                    sampler_candidates=ecfg.sampler_candidates,
                ),
                donate_argnums=(3, 6),
            )
            # cache-aware partial-prefill variants: tokens/plens carry
            # only the uncached suffix, (pre_rows, pre_lens) map the
            # shared prefix pages in. Specialized per (N, S_suffix,
            # P_prefix) bucket; miss-only groups take the plain
            # variants above, so cache-off traffic compiles nothing new.
            self._prefill_pre = jax.jit(
                lambda p, t, plens, c, rows, prow, plen_pre: _argmax_first(
                    T.prefill_paged(
                        cfg, p, t, plens, c, rows,
                        prefix_rows=prow, prefix_lens=plen_pre,
                    )
                ),
                donate_argnums=(3,),
            )
            self._prefill_pre_sampled = jax.jit(
                lambda p, t, plens, c, rows, prow, plen_pre, ft, fl, samp, pres: (
                    T.prefill_paged(
                        cfg, p, t, plens, c, rows,
                        prefix_rows=prow, prefix_lens=plen_pre,
                        full_tokens=ft, full_plens=fl,
                        sampler={**samp, "presence": pres},
                        sampler_candidates=ecfg.sampler_candidates,
                    )
                ),
                donate_argnums=(3, 10),
            )
            # One throwaway all-idle decode step (every slot masked to the
            # trash page): compiles the decode program up front AND leaves
            # the pools with the aval/layout the decode step produces —
            # the steady state every later program sees. Without this,
            # each prefill bucket compiled against freshly-initialized
            # pools is compiled a SECOND time at serving time, a
            # multi-hundred-ms hiccup per bucket mid-traffic.
            zeros = jnp.zeros((ecfg.max_slots,), jnp.int32)
            table0 = jnp.zeros_like(jnp.asarray(self.kv.page_table))
            _, self.kv.buffers = self._decode(
                self.params, self.kv.buffers, zeros, zeros, table0
            )
            _, self.kv.buffers, self._presence = self._decode_sampled(
                self.params,
                self.kv.buffers,
                zeros,
                zeros,
                table0,
                self._decode_sampler(np.zeros((ms,), np.int32)),
                self._presence,
            )
        self.scheduler = Scheduler(ecfg.max_slots)
        self.stats = ServeStats()
        # radix-tree prefix cache: parked pages reuse free pool space
        # opportunistically and are evicted (LRU) the moment the
        # allocator wants them back — admission is never blocked
        self._prefix = PrefixCache(self.kv) if ecfg.prefix_cache else None
        # slot -> total pages its sequence may ever need (prompt + decode
        # growth). Only pages_for_len(plen) are allocated at admission;
        # the remainder is a *reservation* the admission budget must not
        # hand out twice, or an oversubscribed pool would exhaust
        # mid-decode (alloc_upto raises, losing every in-flight request).
        self._page_need: dict[int, int] = {}
        # slots whose active request needs the sampled step variant
        # (noise or presence state); empty set -> plain fast path
        self._fancy_slots: set[int] = set()
        self._uid = 0
        self._step_idx = 0

    # ---- request intake ----------------------------------------------
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        *,
        eos_id: int | None = None,
        sampling: SamplingParams | None = None,
    ) -> int:
        """Enqueue one request; returns its uid. ``sampling`` attaches
        per-request decoding knobs (default: exact greedy)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size > self.ecfg.max_len:
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds max_len "
                f"{self.ecfg.max_len}"
            )
        lifetime = self.kv.pages_for_len(
            min(prompt.size + max_new_tokens - 1, self.ecfg.max_len)
        )
        if lifetime > self.kv.n_pages - 1:
            # reject what could never admit: with aging on, an
            # impossible request would eventually barrier the queue
            raise ValueError(
                f"request needs {lifetime} lifetime pages but the pool "
                f"has {self.kv.n_pages - 1} (EngineConfig(n_pages=...))"
            )
        cap = self.ecfg.sampler_candidates
        if (
            sampling is not None
            and cap
            and not sampling.is_greedy  # greedy rows never consult top_k
            and sampling.top_k > cap
        ):
            raise ValueError(
                f"top_k {sampling.top_k} exceeds the engine's sampler "
                f"candidate cap {cap} "
                "(EngineConfig(sampler_candidates=...))"
            )
        self._uid += 1
        self.scheduler.submit(
            Request(
                self._uid,
                prompt,
                max_new_tokens,
                eos_id=eos_id,
                sampling=sampling or SamplingParams(),
            )
        )
        return self._uid

    # ---- sampler packing ---------------------------------------------
    def _bind_sampler(self, slot: int, sp: SamplingParams) -> None:
        """Write one request's sampling params into its slot's rows.
        The PRNG base key depends only on the request's seed — never on
        the slot, step, or co-batched requests — so seeded runs are
        reproducible under any admission order."""
        self._samp["temp"][slot] = sp.temperature
        self._samp["top_k"][slot] = sp.top_k
        self._samp["top_p"][slot] = sp.top_p
        self._samp["rep"][slot] = sp.repetition_penalty
        self._samp["key"][slot] = sampling_lib.base_key_data(sp.seed)
        self._samp_dev = None  # rows changed: repack at next use
        if sp.is_plain:
            self._fancy_slots.discard(slot)
        else:
            self._fancy_slots.add(slot)

    def _decode_sampler(self, idx: np.ndarray) -> dict:
        """Pack the slot-indexed sampling state for one decode step.
        ``idx`` (slots,) int32: tokens each slot's request has emitted so
        far (its per-request sample index)."""
        if self._samp_dev is None:
            self._samp_dev = {
                k: jnp.asarray(v) for k, v in self._samp.items()
            }
        return {**self._samp_dev, "idx": jnp.asarray(idx)}

    def _prefill_sampler(self, states: list[SequenceState]) -> dict:
        """Pack per-request sampling params for one admission group
        (sample index 0: the first emitted token)."""
        rows = [st_.slot for st_ in states]
        samp = {
            k: jnp.asarray(v[rows]) for k, v in self._samp.items()
        }
        samp["idx"] = jnp.zeros((len(rows),), jnp.int32)
        samp["slots"] = jnp.asarray(np.asarray(rows, np.int32))
        return samp

    # ---- prefill -----------------------------------------------------
    def _bucket(self, plen: int) -> int:
        """Pad prompt lengths to power-of-two page counts: a handful of
        compiled prefill programs serve every prompt length."""
        nb = min(
            _next_pow2(self.kv.pages_for_len(plen)), self.kv.pages_per_seq
        )
        return nb * self.kv.page

    def _batch_bucket(self, n: int) -> int:
        """Pad admission-group sizes to powers of two (capped at
        ``max_slots``): with S also bucketed, the engine compiles
        O(log slots * log lengths) prefill programs total."""
        return min(_next_pow2(n), self.ecfg.max_slots)

    def _pre_bucket(self, n_pages: int) -> int:
        """Pad prefix-hit page counts to powers of two: partial-prefill
        programs stay O(log) per axis like every other bucket (0 = miss
        -> the plain non-prefix program)."""
        if n_pages == 0:
            return 0
        return min(_next_pow2(n_pages), self.kv.pages_per_seq)

    def _lifetime_pages(self, req) -> int:
        """Worst-case pages a request can ever touch, capped at slot
        capacity. The last generated token is returned but never written
        back (no decode step follows it), so the final write position is
        ``plen + max_new_tokens - 2``."""
        return self.kv.pages_for_len(
            min(req.prompt.size + req.max_new_tokens - 1, self.ecfg.max_len)
        )

    def _alloc(self, slot: int, pos: int) -> None:
        """Grow ``slot`` to cover ``pos``, evicting LRU parked prefix
        pages into the free list first if the allocator would otherwise
        run dry — parked pages are opportunistic and never block a live
        sequence."""
        if self._prefix is not None:
            need = pos // self.kv.page + 1 - self.kv.pages_owned(slot)
            if need > self.kv.free_pages:
                self._prefix.ensure_free(need)
        self.kv.alloc_upto(slot, pos)

    def _ensure_writable(self, slot: int, pos: int) -> None:
        """Copy-on-write guard: a slot must exclusively own the page its
        next token writes into. A shared page (mapped into another slot)
        or a radix-indexed page (its bytes are the tree key's value —
        writing would corrupt future hits) is first replaced by a fresh
        page with a jit'd device-side copy. Page-granular prefix hits
        only ever share *full* pages behind the write position, so this
        fires on future sub-page matching or sequence forking — it is
        the invariant, not a hot path."""
        if self._prefix is None:
            return
        li = pos // self.kv.page
        if li >= self.kv.pages_owned(slot):
            return
        p = int(self.kv.page_table[slot, li])
        if self.kv.refcount(p) > 1 or self._prefix.page_in_tree(p):
            self._prefix.ensure_free(1)
            self.kv.cow_page(slot, li, keep=self._prefix.page_in_tree)
            self.stats.record_cow()

    def _reserved_pages(self) -> int:
        """Pages promised to active sequences for decode growth but not
        yet allocated."""
        return sum(
            max(0, need - self.kv.pages_owned(slot))
            for slot, need in self._page_need.items()
        )

    def _match_and_pin(self, req) -> tuple[list[int], int]:
        """Walk the radix tree for ``req``'s prompt and pin every hit
        page (parked pages become live, live pages gain a reference), so
        nothing this plan relies on can be evicted or freed before the
        admission lands. Returns (pinned pages, admission cost in
        pages): fresh pages the request still needs, plus the parked
        pages the pin just consumed from the evictable budget."""
        if self._prefix is None:
            return [], self._lifetime_pages(req)
        pages = self._prefix.match(req.prompt)
        parked = 0
        for p in pages:
            if self.kv.is_cached(p):
                self.kv.take_cached(p)
                parked += 1
            else:
                self.kv.incref(p)
        return pages, self._lifetime_pages(req) - len(pages) + parked

    def _unpin(self, pages: list[int]) -> None:
        for p in pages:
            self.kv.unpin(p)

    def _plan_admission(self) -> dict[tuple[int, int], list]:
        """One bounded-lookahead pass over the waiting queue: group the
        first ``lookahead`` requests into same-bucket prefill waves that
        fit the current slot and page budget. A request whose pages don't
        fit is *skipped* (not blocking): later, smaller requests in the
        window may still be admitted this step — unless the skipped
        request has already been admitted around ``max_skips`` times, in
        which case the pass stops at it (anti-starvation barrier). The
        budget covers each request's whole lifetime (prompt + decode
        growth), so admission can never oversubscribe into a mid-decode
        out-of-pages crash; with the prefix cache on it counts only
        *uncached* pages (hit pages are shared, parked pages are already
        resident) plus every parked page as evictable headroom.

        Groups are keyed ``(suffix bucket, prefix-page bucket)``; each
        entry carries ``(req, pinned prefix pages)``."""
        groups: dict[tuple[int, int], list] = {}
        free_slots = self.scheduler.num_free_slots
        if free_slots == 0:
            return groups
        budget = self.kv.free_pages - self._reserved_pages()
        if self._prefix is not None:
            budget += self._prefix.evictable_pages()
        skipped: list[tuple[int, Request]] = []
        last_planned = -1
        for wi, req in enumerate(
            self.scheduler.peek_admissible(self.ecfg.lookahead)
        ):
            if free_slots == 0:
                break
            pages, cost = self._match_and_pin(req)
            if cost > budget:
                self._unpin(pages)
                skipped.append((wi, req))
                if (
                    self.ecfg.max_skips
                    and self.scheduler.skip_count(req) >= self.ecfg.max_skips
                ):
                    break  # starved request: stop admitting around it
                continue
            suffix = req.prompt.size - len(pages) * self.kv.page
            key = (self._bucket(suffix), self._pre_bucket(len(pages)))
            groups.setdefault(key, []).append((req, pages))
            free_slots -= 1
            budget -= cost
            last_planned = wi
        # a request ages only when this pass admitted *around* it
        # (someone behind it in the window got a slot)
        self.scheduler.note_skips(
            [req for wi, req in skipped if wi < last_planned]
        )
        return groups

    def _admit_group(
        self, plans: list, s: int, npre: int
    ) -> list[SequenceState]:
        """Admit one same-bucket group: ONE jit'd ``prefill_paged`` call
        over tokens (N, S) and ONE host sync for all N requests. Page
        allocation is trimmed to each real prompt — bucket-padding keys
        scatter to the trash page.

        ``plans`` carries ``(req, pinned prefix pages)`` pairs sharing
        the ``(S suffix, npre prefix-page)`` bucket: hit pages are
        adopted straight into the slot's page table (the plan's pin
        becomes the slot's reference) and only the uncached suffix is
        prefilled, attending the prefix through the page table. The hit
        pages are re-indexed in the radix tree only *after* the call's
        host sync — a same-wave duplicate prompt must never read pages
        its own program is still writing."""
        nb = len(plans)
        # step()'s greedy chunking hands over exact power-of-two groups,
        # so every call fills its compiled (N, S) program — no batch rows
        # are ever padded
        assert nb == self._batch_bucket(nb)
        n_pages = s // self.kv.page
        tokens = np.zeros((nb, s), np.int32)
        plens = np.empty((nb,), np.int32)
        rows = np.zeros((nb, n_pages), np.int32)
        pre_rows = np.zeros((nb, max(npre, 1)), np.int32)
        pre_lens = np.zeros((nb,), np.int32)
        # full prompts ride along only for the sampled variant's
        # presence seeding (cached prefix tokens count for the
        # repetition penalty); shape is static per group bucket
        full_tokens = np.zeros((nb, npre * self.kv.page + s), np.int32)
        full_plens = np.empty((nb,), np.int32)
        states: list[SequenceState] = []
        for i, (req, pages) in enumerate(plans):
            state = self.scheduler.admit(self._step_idx, request=req)
            assert state is not None
            hit = len(pages) * self.kv.page
            state.prefix_hit_tokens = hit
            self._page_need[state.slot] = self._lifetime_pages(req)
            self._bind_sampler(state.slot, req.sampling)
            if pages:
                self.kv.adopt(state.slot, pages)
            self._alloc(state.slot, state.plen - 1)
            suffix = req.prompt[hit:]
            tokens[i, : suffix.size] = suffix
            plens[i] = suffix.size
            rows[i] = self.kv.suffix_row(
                state.slot, len(pages), state.plen, n_pages
            )
            pre_rows[i, : len(pages)] = pages
            pre_lens[i] = hit
            full_tokens[i, : state.plen] = req.prompt
            full_plens[i] = state.plen
            self.stats.record_prefix_lookup(hit, state.plen, len(pages))
            states.append(state)
        t0 = time.perf_counter()
        with self.mesh:
            # first token picked inside the jit either way: one host
            # sync of N ints. A group of plain (greedy, no-penalty)
            # requests takes the argmax variant and skips all sampler
            # state; one fancy request in the group switches the whole
            # group to the fused-sampler variant (its plain peers still
            # get exact argmax via their temp=0 rows). Miss-only groups
            # (npre == 0) take the plain non-prefix programs — identical
            # to cache-off serving.
            fancy = any(not req.sampling.is_plain for req, _ in plans)
            if npre and fancy:
                toks_dev, self.kv.buffers, self._presence = (
                    self._prefill_pre_sampled(
                        self.params,
                        jnp.asarray(tokens),
                        jnp.asarray(plens),
                        self.kv.buffers,
                        jnp.asarray(rows),
                        jnp.asarray(pre_rows),
                        jnp.asarray(pre_lens),
                        jnp.asarray(full_tokens),
                        jnp.asarray(full_plens),
                        self._prefill_sampler(states),
                        self._presence,
                    )
                )
            elif npre:
                toks_dev, self.kv.buffers = self._prefill_pre(
                    self.params,
                    jnp.asarray(tokens),
                    jnp.asarray(plens),
                    self.kv.buffers,
                    jnp.asarray(rows),
                    jnp.asarray(pre_rows),
                    jnp.asarray(pre_lens),
                )
            elif fancy:
                toks_dev, self.kv.buffers, self._presence = (
                    self._prefill_sampled(
                        self.params,
                        jnp.asarray(tokens),
                        jnp.asarray(plens),
                        self.kv.buffers,
                        jnp.asarray(rows),
                        self._prefill_sampler(states),
                        self._presence,
                    )
                )
            else:
                toks_dev, self.kv.buffers = self._prefill(
                    self.params,
                    jnp.asarray(tokens),
                    jnp.asarray(plens),
                    self.kv.buffers,
                    jnp.asarray(rows),
                )
            toks = np.asarray(jax.block_until_ready(toks_dev))
        dt = time.perf_counter() - t0
        self.stats.record_prefill(
            int(plens.sum()),
            dt,
            emitted=len(states),
            batch=len(states),
            bucket=(nb, s),
        )
        for i, state in enumerate(states):
            state.generated.append(int(toks[i]))
            state.pos = state.plen
            if self._prefix is not None:
                # index the prompt's full pages (hits refresh their LRU
                # tick; new full pages — suffix included — become
                # matchable the moment their contents are synced)
                self._prefix.insert(
                    state.request.prompt,
                    self.kv.page_table[state.slot],
                )
        return states

    # ---- stepping ----------------------------------------------------
    def step(self) -> list[FinishedRequest]:
        """One scheduler iteration: admit (batched) -> decode -> evict.

        Same-bucket groups are split greedily into power-of-two chunks
        (4 -> one call of 4; 3 -> 2+1) capped at ``max_prefill_batch``:
        every chunk exactly fills its compiled (N, S) program, so batching
        never pays for padded batch rows."""
        finished: list[FinishedRequest] = []
        cap = self.ecfg.max_prefill_batch
        for (s, npre), plans in self._plan_admission().items():
            i = 0
            while i < len(plans):
                n = 1 << (min(len(plans) - i, cap).bit_length() - 1)
                for state in self._admit_group(plans[i : i + n], s, npre):
                    if state.done:  # max_new_tokens == 1 or instant EOS
                        finished.append(self._finish(state))
                i += n

        # a prompt that already fills its slot cannot take a decode step
        for st_ in list(self.scheduler.active()):
            if st_.pos >= self.ecfg.max_len:
                finished.append(self._finish(st_, reason="capacity"))

        active = self.scheduler.active()
        if active:
            tokens = np.zeros((self.ecfg.max_slots,), np.int32)
            positions = np.zeros((self.ecfg.max_slots,), np.int32)
            idx = np.zeros((self.ecfg.max_slots,), np.int32)
            for st_ in active:
                self._ensure_writable(st_.slot, st_.pos)
                self._alloc(st_.slot, st_.pos)
                tokens[st_.slot] = st_.generated[-1]
                positions[st_.slot] = st_.pos
                idx[st_.slot] = len(st_.generated)
            t0 = time.perf_counter()
            with self.mesh:
                # token picked inside the jit'd step either way: the one
                # host sync fetches (slots,) ids. All-plain traffic takes
                # the argmax variant (zero sampling overhead); any fancy
                # active slot switches the step to the fused sampler.
                if self._fancy_slots:
                    toks_dev, self.kv.buffers, self._presence = (
                        self._decode_sampled(
                            self.params,
                            self.kv.buffers,
                            jnp.asarray(tokens),
                            jnp.asarray(positions),
                            jnp.asarray(self.kv.page_table),
                            self._decode_sampler(idx),
                            self._presence,
                        )
                    )
                else:
                    toks_dev, self.kv.buffers = self._decode(
                        self.params,
                        self.kv.buffers,
                        jnp.asarray(tokens),
                        jnp.asarray(positions),
                        jnp.asarray(self.kv.page_table),
                    )
                nxt = np.asarray(jax.block_until_ready(toks_dev))
            dt = time.perf_counter() - t0
            self.stats.record_decode_step(
                len(active), self.ecfg.max_slots, dt
            )
            for st_ in active:
                st_.pos += 1
                st_.generated.append(int(nxt[st_.slot]))
                if st_.done:
                    finished.append(self._finish(st_))
                elif st_.pos >= self.ecfg.max_len:
                    finished.append(self._finish(st_, reason="capacity"))
        self._step_idx += 1
        return finished

    def _finish(
        self, state: SequenceState, *, reason: str | None = None
    ) -> FinishedRequest:
        # Early-finish reclamation: pages the lifetime budget reserved
        # but the sequence never touched (EOS before max_new_tokens) go
        # straight back to the admission budget — popping the need entry
        # releases the reservation, freeing the slot returns the
        # allocated pages — and are counted for the stats.
        need = self._page_need.pop(state.slot, 0)
        reclaimed = max(0, need - self.kv.pages_owned(state.slot))
        self.scheduler.evict(state.slot)
        # radix-indexed pages are parked (refcount 0, device-resident)
        # instead of freed: a future prompt sharing the prefix maps them
        # straight back in, and eviction reclaims them on demand
        self.kv.free_slot(
            state.slot,
            keep=None if self._prefix is None else self._prefix.page_in_tree,
        )
        self._fancy_slots.discard(state.slot)
        if reclaimed:
            self.stats.record_reclaimed(reclaimed)
        self.stats.record_finish(
            kind=state.request.sampling.kind, tokens=len(state.generated)
        )
        if reason is None:
            eos = state.request.eos_id
            reason = (
                "eos"
                if eos is not None and state.generated[-1] == eos
                else "length"
            )
        return FinishedRequest(
            uid=state.request.uid,
            prompt=state.request.prompt,
            tokens=np.asarray(state.generated, np.int32),
            finish_reason=reason,
            admit_step=state.admit_step,
            finish_step=self._step_idx,
            prefix_hit_tokens=state.prefix_hit_tokens,
        )

    def drain(self, max_steps: int | None = None) -> list[FinishedRequest]:
        """Step until every submitted request has finished."""
        out: list[FinishedRequest] = []
        steps = 0
        while not self.scheduler.idle:
            out.extend(self.step())
            steps += 1
            if (
                max_steps is not None
                and steps >= max_steps
                and not self.scheduler.idle
            ):
                raise RuntimeError(
                    f"drain did not converge in {max_steps} steps"
                )
        return out

    def reset_stats(self) -> None:
        """Zero the per-run counters (benchmark repeats); the radix
        tree's contents survive — only the numbers reset."""
        self.stats = ServeStats()
        if self._prefix is not None:
            self._prefix.stats = PrefixStats()

    def stats_summary(self) -> dict:
        out = self.stats.summary()
        if self._prefix is not None:
            out["prefix_cache"].update(self._prefix.stats.snapshot())
            out["prefix_cache"]["enabled"] = True
            out["prefix_cache"]["cached_pages"] = self.kv.cached_pages
        return out
