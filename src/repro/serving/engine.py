"""Continuous-batching serving engine over the block-paged KV cache.

``Engine.submit()`` enqueues requests; each ``step()`` drains the waiting
queue in one admission pass — the first ``lookahead`` queued requests are
grouped by prefill bucket and each same-bucket group is admitted with ONE
jit'd batched prefill call and ONE host sync (no per-request prefill
loop, and an oversized head-of-queue request no longer blocks smaller
ones behind it) — then runs ONE jit'd decode step over all slots (ragged
per-slot positions, idle slots masked to the trash page), and evicts
finished sequences so their slot and pages are reusable the very next
step. ``drain()`` loops until the queue and slots are empty.

The waiting queue is *priority-ordered* (``ScheduleParams``: priority
desc, earliest soft deadline first within a class, FCFS last), and
admission may **preempt**: when a higher-priority request is blocked on
slots or pages, the engine swaps out the lowest-priority /
longest-remaining running sequence — its private KV pages move to host
memory via an async device→host copy overlapped with the next decode
step, shared/radix-indexed pages are pinned or parked in place, never
copied (``repro.serving.swap``) — and the victim's request re-enters the
queue to resume bit-exactly later. Hysteresis
(``EngineConfig(preempt_min_steps=)``) keeps one burst from thrashing
swap: a sequence must run that many steps after each admit/resume before
it can be victimized.

The decode step is always shaped ``(max_slots,)`` and prefill shapes are
bucketed to power-of-two page counts *and* power-of-two batch sizes
(groups split greedily into exact power-of-two chunks, so every call
fills its compiled program), so the engine compiles a handful of programs
total no matter how ragged the traffic is. Page allocation is trimmed to
the real prompt length — bucket padding never pins real pages.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.guards import compile_events_total, hot_path
from repro.configs.base import ModelConfig
from repro.obs import (
    NULL_TRACER,
    BurnRateMonitor,
    FlightRecorder,
    MetricsRegistry,
    SloConfig,
    SpikeDetector,
    Tracer,
    WindowedView,
)
from repro.obs.slo import CRITICAL
from repro.distributed import sharding
from repro.models import transformer as T
from repro.serving import sampling as sampling_lib
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix import PrefixCache, PrefixStats
from repro.serving.request import (
    REJECT_SHED,
    REJECT_TIMEOUT,
    REJECT_TOO_LARGE,
    FinishedRequest,
    Request,
    ScheduleParams,
    SequenceState,
)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler
from repro.serving.stats import ServeStats
from repro.serving.swap import SwapManager, SwapStats

__all__ = ["Engine", "EngineConfig"]


class EngineConfig:
    """Serving knobs: ``max_slots`` concurrent sequences, each with
    ``max_len`` tokens of page-granular KV capacity. ``lookahead`` bounds
    how many waiting requests one admission pass may inspect (default
    ``2 * max_slots``): within that window smaller requests may be
    admitted past an oversized head-of-queue one. ``max_prefill_batch``
    caps how many same-bucket requests share one jit'd prefill call
    (0 -> ``max_slots``; 1 reproduces per-request admission, kept as the
    benchmark baseline). ``max_skips`` bounds starvation: a waiting
    request that ``max_skips`` admission passes have admitted *around*
    (lookahead picked later, smaller requests over it) becomes a
    barrier — nothing behind it is admitted until it fits (0 disables
    aging). ``prefix_cache`` turns on radix-tree prefix reuse: admission
    maps cached prompt-prefix pages straight into the new slot's page
    table and prefills only the uncached suffix (``repro.serving.prefix``).

    ``preemption`` lets a blocked higher-priority request swap out a
    running strictly-lower-priority sequence (pages to host memory,
    ``repro.serving.swap``) instead of waiting for it to finish;
    ``preempt_min_steps`` is the hysteresis — a sequence may only be
    victimized after running that many steps since its last
    admit/resume, so a burst preempts once, not every step.

    ``trace`` turns on span tracing (``repro.obs``): True for the
    default ring capacity, an int for an explicit event capacity. Off
    (the default), the engine binds the no-op tracer and does zero
    tracing work.

    ``monitor`` turns on the live telemetry plane (``repro.obs.windows``):
    True for a 30 s rolling window, a float for an explicit window in
    seconds — the engine ticks a ``WindowedView`` once per step and
    samples device-memory gauges, and ``windowed_vars()`` / the
    ``/vars`` endpoint answer over it. ``slo`` attaches a multi-window
    burn-rate monitor (``repro.obs.slo.SloConfig``; implies monitoring,
    and widens the window to cover its slow timescale).  ``flight_dir``
    arms the flight recorder: anomalies (decode-step time exceeding
    ``spike_factor`` times the warm EWMA baseline, post-warmup step
    compiles, SLO CRITICAL transitions) snapshot the tracer ring +
    metrics + config into incident bundles under that directory.  All
    four default off — a bare engine does zero live-plane work."""

    def __init__(
        self,
        max_slots: int = 8,
        max_len: int = 512,
        *,
        lookahead: int | None = None,
        max_prefill_batch: int = 0,
        n_pages: int = 0,
        sampler_candidates: int = 64,
        max_skips: int = 64,
        prefix_cache: bool = False,
        preemption: bool = True,
        preempt_min_steps: int = 4,
        trace: bool | int = False,
        monitor: bool | float = False,
        slo: SloConfig | None = None,
        flight_dir: str | None = None,
        spike_factor: float = 8.0,
    ):
        self.max_slots = max_slots
        self.max_len = max_len
        self.n_pages = n_pages  # 0 -> worst-case pool (see PagedKVCache)
        self.lookahead = (
            lookahead if lookahead is not None else 2 * max_slots
        )
        if self.lookahead < 1:
            raise ValueError("lookahead must be >= 1")
        if max_skips < 0:
            raise ValueError("max_skips must be >= 0 (0 disables aging)")
        self.max_skips = max_skips
        self.prefix_cache = prefix_cache
        self.preemption = preemption
        if trace is not True and trace is not False and int(trace) < 0:
            raise ValueError("trace must be a bool or a capacity >= 0")
        self.trace = trace
        # identity checks, not equality: 1.0 == True in Python, and a
        # 1-second window must not be mistaken for the bool default
        if (
            monitor is not True
            and monitor is not False
            and float(monitor) <= 0
        ):
            raise ValueError(
                "monitor must be a bool or a window in seconds > 0"
            )
        self.monitor = monitor
        if slo is not None and not isinstance(slo, SloConfig):
            raise TypeError("slo must be a repro.obs.SloConfig or None")
        self.slo = slo
        self.flight_dir = flight_dir
        if spike_factor <= 1.0:
            raise ValueError("spike_factor must be > 1")
        self.spike_factor = spike_factor
        if preempt_min_steps < 1:
            raise ValueError("preempt_min_steps must be >= 1")
        self.preempt_min_steps = preempt_min_steps
        self.max_prefill_batch = max_prefill_batch or max_slots
        if not 1 <= self.max_prefill_batch <= max_slots:
            raise ValueError(
                f"max_prefill_batch {self.max_prefill_batch} must be in "
                f"[1, max_slots={max_slots}]"
            )
        # static candidate cap for the fused sampler: the sampled branch
        # draws from the top-C logits (lax.top_k, O(V log C)) instead of
        # full-vocab sorting (O(V log V) — ~100ms/step at 50k vocab).
        # Requests may not ask for top_k beyond it (Engine.submit
        # raises). 0 -> uncapped exact full-vocab semantics.
        self.sampler_candidates = sampler_candidates or None
        if sampler_candidates < 0:
            raise ValueError("sampler_candidates must be >= 0")

    @property
    def monitor_window_s(self) -> float | None:
        """Effective rolling-window retention in seconds (None = the
        live plane is off). ``slo`` implies monitoring and widens the
        window to cover its slow burn timescale."""
        if self.monitor is False and self.slo is None:
            return None
        if self.monitor is True or self.monitor is False:
            w = 30.0
        else:
            w = float(self.monitor)
        if self.slo is not None:
            w = max(w, self.slo.slow_window_s)
        return w

    def rounded(self, page: int) -> "EngineConfig":
        max_len = -(-self.max_len // page) * page
        return EngineConfig(
            self.max_slots,
            max_len,
            lookahead=self.lookahead,
            max_prefill_batch=self.max_prefill_batch,
            n_pages=self.n_pages,
            sampler_candidates=self.sampler_candidates or 0,
            max_skips=self.max_skips,
            prefix_cache=self.prefix_cache,
            preemption=self.preemption,
            preempt_min_steps=self.preempt_min_steps,
            trace=self.trace,
            monitor=self.monitor,
            slo=self.slo,
            flight_dir=self.flight_dir,
            spike_factor=self.spike_factor,
        )


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 1 else 1


def _argmax_first(out):
    """(logits, *rest) -> (argmax token ids, *rest): fuses the greedy
    pick into the plain jit variants so they, too, sync token ids only."""
    logits, *rest = out
    return (jnp.argmax(logits, axis=-1).astype(jnp.int32), *rest)


class _Plan:
    """One admission pass's outcome: prefill ``groups`` keyed by
    ``(suffix bucket, prefix-page bucket)``, swapped sequences to
    ``resume`` (``(req, pinned pages)`` in priority order), the leftover
    page ``budget``/``free_slots``, and — when a request could not be
    planned for resource reasons — the highest-priority ``blocked``
    request, the preemption trigger."""

    def __init__(self):
        self.groups: dict[tuple[int, int], list] = {}
        self.resumes: list[tuple[Request, list[int]]] = []
        self.blocked: Request | None = None
        self.budget = 0
        self.free_slots = 0


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        *,
        engine_cfg: EngineConfig | None = None,
        strategy: str = "fsdp",
        seed: int = 0,
        params=None,
        paged_impl: str | None = None,
    ):
        """``paged_impl`` selects the paged decode-attention read:
        "gather" (portable jnp reference), "pallas" (fused page-pool
        TPU kernel), or "interpret" (the kernel body interpreted, for
        validation). None picks per platform like ``kernels.ops``."""
        self.mesh = mesh
        st = sharding.Strategy(mesh, strategy)
        self.cfg = cfg = cfg.replace(tp_size=st.tp_size, batch_axes=st.batch)
        self.st = st
        ecfg = (engine_cfg or EngineConfig()).rounded(cfg.attn_block)
        self.ecfg = ecfg
        with mesh:
            if params is None:
                key = jax.random.PRNGKey(seed)
                pshape = jax.eval_shape(lambda k: T.init_model(k, cfg), key)
                psh = sharding.param_shardings(st, pshape)
                params = jax.jit(
                    lambda k: T.init_model(k, cfg), out_shardings=psh
                )(key)
            self.params = params
            self.kv = PagedKVCache(
                cfg,
                ecfg.max_slots,
                ecfg.max_len,
                n_pages=ecfg.n_pages,
                strategy=st,
            )
            if paged_impl is None:
                from repro.kernels.ops import default_impl

                paged_impl = (
                    "pallas" if default_impl() == "pallas" else "gather"
                )
            if paged_impl not in ("gather", "pallas", "interpret"):
                raise ValueError(
                    f"unknown paged_impl {paged_impl!r}; expected "
                    "'gather', 'pallas' or 'interpret'"
                )
            from repro.kernels.ops import paged_impl_for_mesh

            # sharded pools force the gather path: the Pallas kernel has
            # no SPMD partitioning rule (see kernels.ops)
            paged_impl = paged_impl_for_mesh(paged_impl, st.tp_size)
            self.paged_impl = paged_impl
            # Slot-indexed sampling state. The host-side (slots,) param
            # rows are written at admission; each step packs them into
            # device arrays so the sampler runs INSIDE the jit'd step —
            # the jit returns token ids, and sampled decode keeps the
            # greedy baseline's single host sync per step. ``presence``
            # ((slots, V+1) bool, col V absorbs padding) tracks each
            # slot's prompt+generated tokens for the repetition penalty
            # and stays device-resident, threaded through both jits.
            ms = ecfg.max_slots
            self._samp = {
                "temp": np.zeros((ms,), np.float32),
                "top_k": np.zeros((ms,), np.int32),
                "top_p": np.ones((ms,), np.float32),
                "rep": np.ones((ms,), np.float32),
                "key": np.zeros((ms, 2), np.uint32),
                # prompt length per slot: the decode step derives each
                # row's sample index in-jit (idx = pos - plen + 1), so
                # steady-state sampled decode uploads NO per-step
                # sampler state at all
                "plen": np.ones((ms,), np.int32),
            }
            # device copy of the packed rows; params change only at
            # admission, so steady-state sampled decode re-uses the
            # cached arrays instead of re-transferring 6 arrays a step
            self._samp_dev: dict | None = None
            self._presence = jnp.zeros(
                (ms, cfg.padded_vocab + 1), jnp.bool_
            )
            # Two compiled variants per step kind. The *plain* variant
            # (in-jit argmax, no sampler state — greedy traffic's fast
            # path, zero sampling overhead) serves steps where no active
            # request needs noise or the presence buffer; the *sampled*
            # variant fuses the full sampler. Both decode variants are
            # warmed at init so neither compiles mid-traffic. Presence
            # rides as its own (donatable) arg; the small (slots,) param
            # arrays are device-cached between admissions.
            self._decode = jax.jit(
                lambda p, c, t, pos, pt: _argmax_first(
                    T.decode_step_paged(
                        cfg, p, c, t, pos, pt, paged_impl=paged_impl
                    )
                ),
                donate_argnums=(1,),
            )
            self._decode_sampled = jax.jit(
                lambda p, c, t, pos, pt, samp, pres: T.decode_step_paged(
                    cfg, p, c, t, pos, pt, paged_impl=paged_impl,
                    sampler={
                        **samp,
                        # per-request sample index, derived in-jit: the
                        # request in this slot has emitted pos - plen + 1
                        # tokens (idle slots' values are ignored)
                        "idx": pos - samp["plen"] + 1,
                        "presence": pres,
                    },
                    sampler_candidates=ecfg.sampler_candidates,
                ),
                donate_argnums=(1, 6),
            )
            # one wrapper; jax.jit specializes per (N, S) bucket shape
            self._prefill = jax.jit(
                lambda p, t, plens, c, rows: _argmax_first(
                    T.prefill_paged(cfg, p, t, plens, c, rows)
                ),
                donate_argnums=(3,),
            )
            self._prefill_sampled = jax.jit(
                lambda p, t, plens, c, rows, samp, pres: T.prefill_paged(
                    cfg, p, t, plens, c, rows,
                    sampler={**samp, "presence": pres},
                    sampler_candidates=ecfg.sampler_candidates,
                ),
                donate_argnums=(3, 6),
            )
            # cache-aware partial-prefill variants: tokens/plens carry
            # only the uncached suffix, (pre_rows, pre_lens) map the
            # shared prefix pages in. Specialized per (N, S_suffix,
            # P_prefix) bucket; miss-only groups take the plain
            # variants above, so cache-off traffic compiles nothing new.
            self._prefill_pre = jax.jit(
                lambda p, t, plens, c, rows, prow, plen_pre: _argmax_first(
                    T.prefill_paged(
                        cfg, p, t, plens, c, rows,
                        prefix_rows=prow, prefix_lens=plen_pre,
                    )
                ),
                donate_argnums=(3,),
            )
            self._prefill_pre_sampled = jax.jit(
                lambda p, t, plens, c, rows, prow, plen_pre, ft, fl, samp, pres: (
                    T.prefill_paged(
                        cfg, p, t, plens, c, rows,
                        prefix_rows=prow, prefix_lens=plen_pre,
                        full_tokens=ft, full_plens=fl,
                        sampler={**samp, "presence": pres},
                        sampler_candidates=ecfg.sampler_candidates,
                    )
                ),
                donate_argnums=(3, 10),
            )
            # presence rebuild for a *resumed* fancy sequence: one jit'd
            # scatter of its prompt+generated tokens (padded with the
            # absorb column V) into the new slot's row — equivalent to
            # the presence the running sequence had accumulated
            npad = cfg.padded_vocab
            self._seed_presence = jax.jit(
                lambda pres, slot, toks: pres.at[slot].set(False)
                .at[slot, toks]
                .set(True),
                donate_argnums=(0,),
            )
            self._presence_pad = npad  # absorb column for padding
            # One throwaway all-idle decode step (every slot masked to the
            # trash page): compiles the decode program up front AND leaves
            # the pools with the aval/layout the decode step produces —
            # the steady state every later program sees. Without this,
            # each prefill bucket compiled against freshly-initialized
            # pools is compiled a SECOND time at serving time, a
            # multi-hundred-ms hiccup per bucket mid-traffic.
            zeros = jnp.zeros((ecfg.max_slots,), jnp.int32)
            table0 = jnp.zeros_like(jnp.asarray(self.kv.page_table))
            _, self.kv.buffers = self._decode(
                self.params, self.kv.buffers, zeros, zeros, table0
            )
            _, self.kv.buffers, self._presence = self._decode_sampled(
                self.params,
                self.kv.buffers,
                zeros,
                zeros,
                table0,
                self._decode_sampler(),
                self._presence,
            )
        # Observability: one shared metrics registry (ServeStats /
        # SwapStats / PrefixStats are views over it; `repro.obs.prom`
        # renders it) and a span tracer. With trace off the engine
        # binds the no-op tracer — call sites below stay branch-free
        # and cost one no-op call each.
        self.metrics = MetricsRegistry()
        if ecfg.trace:
            self.tracer = Tracer(
                capacity=(1 << 16) if ecfg.trace is True else int(ecfg.trace)
            )
        else:
            self.tracer = NULL_TRACER
        self._intern_trace_ids()
        self.scheduler = Scheduler(
            ecfg.max_slots,
            on_event=self._sched_event if self.tracer.enabled else None,
        )
        self.stats = ServeStats(self.metrics)
        # radix-tree prefix cache: parked pages reuse free pool space
        # opportunistically and are evicted (LRU) the moment the
        # allocator wants them back — admission is never blocked
        self._prefix = (
            PrefixCache(self.kv, metrics=self.metrics)
            if ecfg.prefix_cache
            else None
        )
        # host-memory page swap for preemption (always constructed: the
        # machinery is inert until a preemption actually fires)
        self.swap = SwapManager(
            self.kv,
            page_in_tree=(
                self._prefix.page_in_tree if self._prefix else None
            ),
            metrics=self.metrics,
        )
        # uid -> (SequenceState, SwapRecord) for swapped-out sequences;
        # their Requests sit back in the scheduler's waiting queue and
        # resume (swap-in) instead of prefilling when re-admitted
        self._swapped: dict[int, tuple[SequenceState, object]] = {}
        # swap records whose device→host staging copy is still in
        # flight; finalized right after the next decode step
        self._pending_swaps: list = []
        # structured rejections awaiting delivery by the next step()
        self._rejected: list[FinishedRequest] = []
        # slot -> total pages its sequence may ever need (prompt + decode
        # growth). Only pages_for_len(plen) are allocated at admission;
        # the remainder is a *reservation* the admission budget must not
        # hand out twice, or an oversubscribed pool would exhaust
        # mid-decode (alloc_upto raises, losing every in-flight request).
        self._page_need: dict[int, int] = {}
        # slot -> unconsumed COW-page reservations. A prefix-hit slot
        # maps shared/radix-indexed pages it must not write into; if a
        # write ever lands there (sub-page matching, forking),
        # _ensure_writable needs ONE fresh page for the split. That page
        # is budgeted at admission (folded into _page_need) — without
        # it, an oversubscribed pool can be legitimately dry when the
        # split fires and cow_page raises mid-decode, killing every
        # in-flight request.
        self._cow_reserve: dict[int, int] = {}
        # slots whose active request needs the sampled step variant
        # (noise or presence state); empty set -> plain fast path
        self._fancy_slots: set[int] = set()
        self._uid = 0
        self._step_idx = 0
        # ---- live telemetry plane (all opt-in; see EngineConfig) -----
        window_s = ecfg.monitor_window_s
        # re-evaluates self.metrics every tick, so the view follows
        # reset_stats()'s registry swap (window restarts from zero)
        self._window = (
            WindowedView(lambda: self.metrics, window_s=window_s)
            if window_s is not None
            else None
        )
        # serializes window ticks/reads between the step loop and the
        # /vars scrape thread (MetricsServer handlers)
        self._obs_lock = threading.Lock()
        self._slo_mon = (
            BurnRateMonitor(self._window, ecfg.slo)
            if ecfg.slo is not None
            else None
        )
        self._flight = (
            FlightRecorder(ecfg.flight_dir) if ecfg.flight_dir else None
        )
        # the spike detector exists only to feed the flight recorder
        self._spike = (
            SpikeDetector(factor=ecfg.spike_factor)
            if self._flight is not None
            else None
        )
        # compile-trip captures arm only after the first clean
        # (zero-compile) step: warmup-adjacent compiles — fresh prefill
        # buckets, the sampled variants — are expected, not incidents
        self._flight_armed = False
        self._roofline: dict | None = None
        # backend allocator introspection, probed once: platforms
        # without memory_stats (CPU) silently report 0 bytes
        self._device_memory_stats = None
        if self._window is not None:
            try:
                dev = np.asarray(self.mesh.devices).flat[0]
                fn = getattr(dev, "memory_stats", None)
                if fn is not None and fn():
                    self._device_memory_stats = fn
            except Exception:
                self._device_memory_stats = None

    # ---- observability -----------------------------------------------
    def _intern_trace_ids(self) -> None:
        """Resolve every track/name id the engine will ever record —
        hot-path tracer calls then do no string work at all. (The
        NULL tracer returns 0 for everything; the ids are never used.)"""
        tr = self.tracer
        self._tk_admission = tr.track("engine:admission")
        self._tk_prefill = tr.track("engine:prefill")
        self._tk_decode = tr.track("engine:decode")
        self._tk_sync = tr.track("engine:host_sync")
        self._tk_queue = tr.track("queue")
        self._tk_slot = [
            tr.track(f"slot{i}") for i in range(self.ecfg.max_slots)
        ]
        self._nm_admission = tr.name("admission")
        self._nm_prefill = tr.name("prefill")
        self._nm_decode_step = tr.name("decode_step")
        self._nm_host_sync = tr.name("host_sync")
        self._nm_queued = tr.name("queued")
        self._nm_decode = tr.name("decode")
        self._nm_finished = tr.name("finished")
        self._nm_rejected = tr.name("rejected")
        self._nm_swap_out = tr.name("swap_out")
        self._nm_swap_in = tr.name("swap_in")
        self._nm_preempt = tr.name("preempt")
        self._nm_cow = tr.name("cow_split")
        self._nm_prefix_match = tr.name("prefix_match")
        self._nm_roofline = tr.name("roofline")
        # counter lanes (Perfetto "C" samples, one set per step): pool
        # occupancy, queue depth, running slots render as counter tracks
        # under the span lanes
        self._tk_counters = tr.track("counters")
        self._nm_ctr_live = tr.name("pool_live_pages")
        self._nm_ctr_queue = tr.name("queue_depth")
        self._nm_ctr_running = tr.name("running_slots")
        # scheduler queue-lifecycle instants (see _sched_event)
        self._sched_names = {
            kind: tr.name(kind)
            for kind in ("submit", "admit", "resume", "remove")
        }

    def _sched_event(self, kind: str, req: Request) -> None:
        """Scheduler hook -> queue-track instants (only bound when
        tracing is on, so the off path pays nothing)."""
        self.tracer.instant(self._tk_queue, self._sched_names[kind], req.uid)

    # ---- request intake ----------------------------------------------
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        *,
        eos_id: int | None = None,
        sampling: SamplingParams | None = None,
        schedule: ScheduleParams | None = None,
    ) -> int:
        """Enqueue one request; returns its uid. ``sampling`` attaches
        per-request decoding knobs (default: exact greedy); ``schedule``
        attaches scheduling knobs (priority / soft deadline / max queue
        wait; default: best-effort FCFS).

        A request that could *never* fit the engine's geometry is not
        an exception: it finishes with ``finish_reason "rejected"`` /
        ``reject_reason REJECT_TOO_LARGE``, delivered by the next
        ``step()`` — callers distinguish it from a queue-wait timeout
        (``REJECT_TIMEOUT``) by the reason enum."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        schedule = schedule or ScheduleParams()
        self._uid += 1
        req = Request(
            self._uid,
            prompt,
            max_new_tokens,
            eos_id=eos_id,
            sampling=sampling or SamplingParams(),
            schedule=schedule,
            submit_s=time.perf_counter(),
        )
        cap = self.ecfg.sampler_candidates
        if (
            sampling is not None
            and cap
            and not sampling.is_greedy  # greedy rows never consult top_k
            and sampling.top_k > cap
        ):
            raise ValueError(
                f"top_k {sampling.top_k} exceeds the engine's sampler "
                f"candidate cap {cap} "
                "(EngineConfig(sampler_candidates=...))"
            )
        lifetime = self.kv.pages_for_len(
            min(prompt.size + max_new_tokens - 1, self.ecfg.max_len)
        )
        if (
            prompt.size > self.ecfg.max_len
            or lifetime > self.kv.n_pages - 1
        ):
            # structured rejection for what could never admit: with
            # aging on, an impossible request would eventually barrier
            # the queue forever
            self._rejected.append(self._reject(req, REJECT_TOO_LARGE))
            return self._uid
        self.scheduler.submit(req)
        return self._uid

    def _reject(self, req: Request, reason: str) -> FinishedRequest:
        self.stats.record_reject(
            reason,
            # shed requests are excluded from SLO accounting: shedding
            # is the burn-rate monitor's own *response* to misses, and
            # counting the sheds as new misses would latch CRITICAL
            had_deadline=(
                req.schedule.deadline_s is not None
                and reason != REJECT_SHED
            ),
        )
        self.tracer.instant(self._tk_queue, self._nm_rejected, req.uid)
        return FinishedRequest(
            uid=req.uid,
            prompt=req.prompt,
            tokens=np.zeros((0,), np.int32),
            finish_reason="rejected",
            reject_reason=reason,
            admit_step=-1,
            finish_step=self._step_idx,
            schedule=req.schedule,
        )

    def _expire_waiting(self, finished: list[FinishedRequest]) -> None:
        """Queue-wait timeouts: a never-admitted request whose
        ``max_queue_wait_s`` has elapsed gives up with a structured
        rejection. Swapped-out sequences are exempt — they have already
        run; their re-queued request always resumes eventually."""
        now = time.perf_counter()
        for req in list(self.scheduler.waiting):
            wait = req.schedule.max_queue_wait_s
            if (
                wait is not None
                and req.uid not in self._swapped
                and now - req.submit_s > wait
            ):
                self.scheduler.remove(req)
                finished.append(self._reject(req, REJECT_TIMEOUT))

    # ---- sampler packing ---------------------------------------------
    def _bind_sampler(
        self, slot: int, sp: SamplingParams, plen: int
    ) -> None:
        """Write one request's sampling params into its slot's rows.
        The PRNG base key depends only on the request's seed — never on
        the slot, step, or co-batched requests — so seeded runs are
        reproducible under any admission order."""
        self._samp["temp"][slot] = sp.temperature
        self._samp["top_k"][slot] = sp.top_k
        self._samp["top_p"][slot] = sp.top_p
        self._samp["rep"][slot] = sp.repetition_penalty
        self._samp["key"][slot] = sampling_lib.base_key_data(sp.seed)
        self._samp["plen"][slot] = plen
        self._samp_dev = None  # rows changed: repack at next use
        if sp.is_plain:
            self._fancy_slots.discard(slot)
        else:
            self._fancy_slots.add(slot)

    @hot_path
    def _decode_sampler(self) -> dict:
        """The slot-indexed sampling state for decode steps. Fully
        device-cached between admissions — the per-request sample index
        is derived in-jit from the step's positions (idx = pos - plen +
        1), so steady-state sampled decode transfers nothing."""
        if self._samp_dev is None:
            self._samp_dev = {
                k: jnp.asarray(v) for k, v in self._samp.items()
            }
        return self._samp_dev

    def _prefill_sampler(self, states: list[SequenceState]) -> dict:
        """Pack per-request sampling params for one admission group
        (sample index 0: the first emitted token)."""
        rows = [st_.slot for st_ in states]
        samp = {
            k: jnp.asarray(v[rows]) for k, v in self._samp.items()
        }
        samp["idx"] = jnp.zeros((len(rows),), jnp.int32)
        samp["slots"] = jnp.asarray(np.asarray(rows, np.int32))
        return samp

    # ---- prefill -----------------------------------------------------
    def _bucket(self, plen: int) -> int:
        """Pad prompt lengths to power-of-two page counts: a handful of
        compiled prefill programs serve every prompt length."""
        nb = min(
            _next_pow2(self.kv.pages_for_len(plen)), self.kv.pages_per_seq
        )
        return nb * self.kv.page

    def _batch_bucket(self, n: int) -> int:
        """Pad admission-group sizes to powers of two (capped at
        ``max_slots``): with S also bucketed, the engine compiles
        O(log slots * log lengths) prefill programs total."""
        return min(_next_pow2(n), self.ecfg.max_slots)

    def _pre_bucket(self, n_pages: int) -> int:
        """Pad prefix-hit page counts to powers of two: partial-prefill
        programs stay O(log) per axis like every other bucket (0 = miss
        -> the plain non-prefix program)."""
        if n_pages == 0:
            return 0
        return min(_next_pow2(n_pages), self.kv.pages_per_seq)

    def _lifetime_pages(self, req) -> int:
        """Worst-case pages a request can ever touch, capped at slot
        capacity. The last generated token is returned but never written
        back (no decode step follows it), so the final write position is
        ``plen + max_new_tokens - 2``."""
        return self.kv.pages_for_len(
            min(req.prompt.size + req.max_new_tokens - 1, self.ecfg.max_len)
        )

    def _alloc(self, slot: int, pos: int) -> None:
        """Grow ``slot`` to cover ``pos``, evicting LRU parked prefix
        pages into the free list first if the allocator would otherwise
        run dry — parked pages are opportunistic and never block a live
        sequence."""
        if self._prefix is not None:
            need = pos // self.kv.page + 1 - self.kv.pages_owned(slot)
            if need > self.kv.free_pages:
                self._prefix.ensure_free(need)
        self.kv.alloc_upto(slot, pos)

    def _ensure_writable(self, slot: int, pos: int) -> None:
        """Copy-on-write guard: a slot must exclusively own the page its
        next token writes into. A shared page (mapped into another slot)
        or a radix-indexed page (its bytes are the tree key's value —
        writing would corrupt future hits) is first replaced by a fresh
        page with a jit'd device-side copy. Page-granular prefix hits
        only ever share *full* pages behind the write position, so this
        fires on sub-page matching or sequence forking — it is the
        invariant, not a hot path.

        The fresh page comes out of the slot's COW reservation
        (``_cow_reserve``, budgeted at prefix-hit admission): the split
        replaces a mapping rather than growing the sequence, so the
        slot's remaining lifetime draw shrinks by one — consuming the
        reservation keeps ``_reserved_pages`` exact and guarantees the
        pool is never dry here even when fully oversubscribed."""
        if self._prefix is None:
            return
        li = pos // self.kv.page
        if li >= self.kv.pages_owned(slot):
            return
        p = int(self.kv.page_table[slot, li])
        if self.kv.refcount(p) > 1 or self._prefix.page_in_tree(p):
            self._prefix.ensure_free(1)
            self.kv.cow_page(slot, li, keep=self._prefix.page_in_tree)
            if self._cow_reserve.get(slot, 0) > 0:
                self._cow_reserve[slot] -= 1
                self._page_need[slot] -= 1
            self.stats.record_cow()
            self.tracer.instant(self._tk_slot[slot], self._nm_cow, p)

    def _reserved_pages(self) -> int:
        """Pages promised to active sequences for decode growth but not
        yet allocated."""
        return sum(
            max(0, need - self.kv.pages_owned(slot))
            for slot, need in self._page_need.items()
        )

    def _match_and_pin(self, req) -> tuple[list[int], int]:
        """Walk the radix tree for ``req``'s prompt and pin every hit
        page (parked pages become live, live pages gain a reference), so
        nothing this plan relies on can be evicted or freed before the
        admission lands. Returns (pinned pages, admission cost in
        pages): fresh pages the request still needs, plus the parked
        pages the pin just consumed from the evictable budget.

        Works for *resumes* too: a swapped-out sequence's resident
        prefix (its swap pins keep the shared pages live, so the tree
        still maps them) comes back through the same walk, and the cost
        formula — lifetime minus resident — prices exactly the fresh
        pages the restore plus future decode growth still need.

        A hit also costs one *COW reserve* page (see ``_cow_reserve``):
        the shared pages the slot adopts are write-protected, and a
        future split must never find the pool dry. A pool-filling
        request (lifetime == every allocatable page) physically cannot
        carry the extra page, so it declines the hit and prefills fresh
        — a miss shares nothing, so it needs no reserve. Resumes are
        exempt from declining: their pinned shared pages were never
        copied to host, so the re-match MUST adopt them."""
        if self._prefix is None:
            return [], self._lifetime_pages(req)
        pages = self._prefix.match(req.prompt)
        lifetime = self._lifetime_pages(req)
        if (
            pages
            and req.uid not in self._swapped
            and lifetime + 1 > self.kv.n_pages - 1
        ):
            return [], lifetime
        parked = 0
        for p in pages:
            if self.kv.is_cached(p):
                self.kv.take_cached(p)
                parked += 1
            else:
                self.kv.incref(p)
        reserve = 1 if pages else 0
        return pages, lifetime - len(pages) + parked + reserve

    def _unpin(self, pages: list[int]) -> None:
        for p in pages:
            self.kv.unpin(p)

    def _plan_admission(self) -> _Plan:
        """One bounded-lookahead pass over the waiting queue (priority
        order): group the first ``lookahead`` requests into same-bucket
        prefill waves — or resume entries for swapped-out sequences —
        that fit the current slot and page budget. A request whose pages
        don't fit is *skipped* (not blocking): later, smaller requests
        in the window may still be admitted this step — unless the
        skipped request has already been admitted around ``max_skips``
        times, in which case the pass stops at it (anti-starvation
        barrier). The budget covers each request's whole lifetime
        (prompt + decode growth), so admission can never oversubscribe
        into a mid-decode out-of-pages crash; with the prefix cache on
        it counts only *uncached* pages (hit pages are shared, parked
        pages are already resident) plus every parked page as evictable
        headroom.

        The highest-priority request that could not be planned is
        reported as ``plan.blocked`` — ``step()`` hands it to the
        preemption path."""
        plan = _Plan()
        plan.free_slots = self.scheduler.num_free_slots
        plan.budget = self.kv.free_pages - self._reserved_pages()
        if self._prefix is not None:
            plan.budget += self._prefix.evictable_pages()
        skipped: list[tuple[int, Request]] = []
        last_planned = -1
        for wi, req in enumerate(
            self.scheduler.peek_admissible(self.ecfg.lookahead)
        ):
            if plan.free_slots == 0:
                if plan.blocked is None:
                    plan.blocked = req
                break
            pages, cost = self._match_and_pin(req)
            if cost > plan.budget:
                self._unpin(pages)
                skipped.append((wi, req))
                if plan.blocked is None:
                    plan.blocked = req
                if (
                    self.ecfg.max_skips
                    and self.scheduler.skip_count(req) >= self.ecfg.max_skips
                ):
                    break  # starved request: stop admitting around it
                continue
            if req.uid in self._swapped:
                plan.resumes.append((req, pages))
            else:
                suffix = req.prompt.size - len(pages) * self.kv.page
                key = (self._bucket(suffix), self._pre_bucket(len(pages)))
                plan.groups.setdefault(key, []).append((req, pages))
            plan.free_slots -= 1
            plan.budget -= cost
            last_planned = wi
        # a request ages only when this pass admitted *around* it
        # (someone behind it in the window got a slot)
        self.scheduler.note_skips(
            [req for wi, req in skipped if wi < last_planned]
        )
        return plan

    def _unplan(self, plan: _Plan) -> None:
        """Drop every pin a plan holds (it is being recomputed after a
        preemption changed the resource picture)."""
        for plans in plan.groups.values():
            for _, pages in plans:
                self._unpin(pages)
        for _, pages in plan.resumes:
            self._unpin(pages)

    # ---- preemption --------------------------------------------------
    def _swap_pin_len(self, state: SequenceState) -> int:
        """How many of a victim's leading pages swap-out would pin in
        place (shared with other slots) rather than copy — capped at the
        radix match limit so a resume's re-match always covers them."""
        owned = self.kv.owned_pages(state.slot)
        cap = min((state.plen - 1) // self.kv.page, len(owned))
        n = 0
        while n < cap and self.kv.refcount(owned[n]) > 1:
            n += 1
        return n

    def _maybe_preempt(self, plan: _Plan) -> bool:
        """Try to unblock ``plan.blocked`` by swapping out running
        sequences of *strictly lower* priority (lowest priority first,
        longest-remaining first within a class). Hysteresis: only
        sequences that have run ``preempt_min_steps`` since their last
        admit/resume are candidates, so one burst cannot thrash swap.
        Victims are only swapped if they collectively unblock the
        request — pointless preemptions are never issued. Returns True
        if anything was swapped (the caller re-plans)."""
        req = plan.blocked
        if req is None or not self.ecfg.preemption:
            return False
        pr = req.schedule.priority
        cands = [
            st_
            for st_ in self.scheduler.active()
            if st_.request.schedule.priority < pr
            and self._step_idx - st_.resume_step
            >= self.ecfg.preempt_min_steps
        ]
        if not cands:
            return False
        cands.sort(
            key=lambda st_: (
                st_.request.schedule.priority,
                -st_.remaining,
            )
        )
        pages, cost = self._match_and_pin(req)
        self._unpin(pages)
        budget, free_slots = plan.budget, plan.free_slots
        victims: list[SequenceState] = []
        for v in cands:
            if free_slots >= 1 and cost <= budget:
                break
            # swapping v frees its private pages (copied or parked) and
            # releases its unallocated decode-growth reservation; only
            # its pinned shared prefix stays unavailable
            need = self._page_need.get(
                v.slot, self.kv.pages_owned(v.slot)
            )
            budget += need - self._swap_pin_len(v)
            free_slots += 1
            victims.append(v)
        if free_slots < 1 or cost > budget:
            return False  # even every candidate would not unblock it
        for v in victims:
            self._preempt(v)
        return True

    def _preempt(self, state: SequenceState) -> None:
        """Swap one running sequence out to host memory and re-queue its
        request for a later bit-exact resume."""
        slot = state.slot
        uid = state.request.uid
        # close the slot's decode span before its pages move; a1=1 marks
        # the close as a preemption, not a finish
        self.tracer.end(self._tk_slot[slot], self._nm_decode, uid, 1)
        self.tracer.instant(self._tk_slot[slot], self._nm_preempt, uid)
        record = self.swap.swap_out(
            slot, max_pin=(state.plen - 1) // self.kv.page
        )
        self.tracer.instant(
            self._tk_slot[slot], self._nm_swap_out, uid, record.n_host
        )
        self.scheduler.evict(slot)
        self._page_need.pop(slot, None)
        self._cow_reserve.pop(slot, None)
        self._fancy_slots.discard(slot)
        state.preemptions += 1
        self._swapped[state.request.uid] = (state, record)
        self._pending_swaps.append(record)
        self.scheduler.submit(state.request)
        self.stats.record_preemption()

    def _resume(self, req: Request, pages: list[int]) -> SequenceState:
        """Swap a preempted sequence back in: adopt the re-matched
        resident prefix (``pages``, pinned by the plan), allocate fresh
        pages for the rest, scatter the host copies, and rebind the
        slot-indexed sampler/presence state. The token stream continues
        bit-exactly: KV bytes round-trip unchanged, and the sampler's
        noise depends only on (seed, sample index)."""
        state, record = self._swapped.pop(req.uid)
        assert self.scheduler.resume(state, request=req) is not None
        slot = state.slot
        self.tracer.instant(
            self._tk_slot[slot], self._nm_swap_in, req.uid, record.n_host
        )
        self.tracer.begin(self._tk_slot[slot], self._nm_decode, req.uid)
        reserve = 1 if pages else 0
        self._page_need[slot] = self._lifetime_pages(req) + reserve
        self._cow_reserve[slot] = reserve
        self._bind_sampler(slot, req.sampling, state.plen)
        if pages:
            self.kv.adopt(slot, pages)
        self._alloc(slot, record.n_logical * self.kv.page - 1)
        self.swap.swap_in(record, slot, n_resident=len(pages))
        state.resume_step = self._step_idx
        state.prefix_hit_tokens = max(
            state.prefix_hit_tokens, len(pages) * self.kv.page
        )
        if not req.sampling.is_plain:
            # rebuild the slot's presence row: prompt + generated so
            # far, padded into the absorb column
            toks = np.full(
                (self.ecfg.max_len + 1,), self._presence_pad, np.int32
            )
            seen = np.concatenate(
                [req.prompt, np.asarray(state.generated, np.int32)]
            )[: self.ecfg.max_len + 1]
            toks[: seen.size] = seen
            with self.mesh:
                self._presence = self._seed_presence(
                    self._presence,
                    jnp.asarray(slot, jnp.int32),
                    jnp.asarray(toks),
                )
        self.stats.record_resume()
        return state

    # ---- admission ---------------------------------------------------
    def _admit_group(
        self, plans: list, s: int, npre: int
    ) -> list[SequenceState]:
        """Admit one same-bucket group: ONE jit'd ``prefill_paged`` call
        over tokens (N, S) and ONE host sync for all N requests. Page
        allocation is trimmed to each real prompt — bucket-padding keys
        scatter to the trash page.

        ``plans`` carries ``(req, pinned prefix pages)`` pairs sharing
        the ``(S suffix, npre prefix-page)`` bucket: hit pages are
        adopted straight into the slot's page table (the plan's pin
        becomes the slot's reference) and only the uncached suffix is
        prefilled, attending the prefix through the page table. The hit
        pages are re-indexed in the radix tree only *after* the call's
        host sync — a same-wave duplicate prompt must never read pages
        its own program is still writing."""
        nb = len(plans)
        # step()'s greedy chunking hands over exact power-of-two groups,
        # so every call fills its compiled (N, S) program — no batch rows
        # are ever padded
        assert nb == self._batch_bucket(nb)
        n_pages = s // self.kv.page
        tokens = np.zeros((nb, s), np.int32)
        plens = np.empty((nb,), np.int32)
        rows = np.zeros((nb, n_pages), np.int32)
        pre_rows = np.zeros((nb, max(npre, 1)), np.int32)
        pre_lens = np.zeros((nb,), np.int32)
        # full prompts ride along only for the sampled variant's
        # presence seeding (cached prefix tokens count for the
        # repetition penalty); shape is static per group bucket
        full_tokens = np.zeros((nb, npre * self.kv.page + s), np.int32)
        full_plens = np.empty((nb,), np.int32)
        states: list[SequenceState] = []
        t_admit = time.perf_counter()
        for i, (req, pages) in enumerate(plans):
            state = self.scheduler.admit(self._step_idx, request=req)
            assert state is not None
            state.resume_step = self._step_idx
            hit = len(pages) * self.kv.page
            state.prefix_hit_tokens = hit
            # queue wait: submit -> this admission pass. The tracer gets
            # it as an X span on the queue track (start = submit time,
            # same perf_counter clock the ns stamps use).
            wait = t_admit - req.submit_s
            self.stats.record_queue_wait(wait)
            self.tracer.complete(
                self._tk_queue,
                self._nm_queued,
                int(req.submit_s * 1e9),
                int(wait * 1e9),
                req.uid,
            )
            if pages:
                self.tracer.instant(
                    self._tk_slot[state.slot],
                    self._nm_prefix_match,
                    req.uid,
                    len(pages),
                )
            # a prefix hit carries one extra budgeted page: the COW
            # reserve for a future split of an adopted shared page
            reserve = 1 if pages else 0
            self._page_need[state.slot] = (
                self._lifetime_pages(req) + reserve
            )
            self._cow_reserve[state.slot] = reserve
            self._bind_sampler(state.slot, req.sampling, state.plen)
            if pages:
                self.kv.adopt(state.slot, pages)
            self._alloc(state.slot, state.plen - 1)
            suffix = req.prompt[hit:]
            tokens[i, : suffix.size] = suffix
            plens[i] = suffix.size
            rows[i] = self.kv.suffix_row(
                state.slot, len(pages), state.plen, n_pages
            )
            pre_rows[i, : len(pages)] = pages
            pre_lens[i] = hit
            full_tokens[i, : state.plen] = req.prompt
            full_plens[i] = state.plen
            self.stats.record_prefix_lookup(hit, state.plen, len(pages))
            states.append(state)
        t0 = time.perf_counter()
        t0_ns = self.tracer.begin(
            self._tk_prefill, self._nm_prefill, s, nb
        )
        with self.mesh:
            # first token picked inside the jit either way: one host
            # sync of N ints. A group of plain (greedy, no-penalty)
            # requests takes the argmax variant and skips all sampler
            # state; one fancy request in the group switches the whole
            # group to the fused-sampler variant (its plain peers still
            # get exact argmax via their temp=0 rows). Miss-only groups
            # (npre == 0) take the plain non-prefix programs — identical
            # to cache-off serving.
            fancy = any(not req.sampling.is_plain for req, _ in plans)
            if npre and fancy:
                toks_dev, self.kv.buffers, self._presence = (
                    self._prefill_pre_sampled(
                        self.params,
                        jnp.asarray(tokens),
                        jnp.asarray(plens),
                        self.kv.buffers,
                        jnp.asarray(rows),
                        jnp.asarray(pre_rows),
                        jnp.asarray(pre_lens),
                        jnp.asarray(full_tokens),
                        jnp.asarray(full_plens),
                        self._prefill_sampler(states),
                        self._presence,
                    )
                )
            elif npre:
                toks_dev, self.kv.buffers = self._prefill_pre(
                    self.params,
                    jnp.asarray(tokens),
                    jnp.asarray(plens),
                    self.kv.buffers,
                    jnp.asarray(rows),
                    jnp.asarray(pre_rows),
                    jnp.asarray(pre_lens),
                )
            elif fancy:
                toks_dev, self.kv.buffers, self._presence = (
                    self._prefill_sampled(
                        self.params,
                        jnp.asarray(tokens),
                        jnp.asarray(plens),
                        self.kv.buffers,
                        jnp.asarray(rows),
                        self._prefill_sampler(states),
                        self._presence,
                    )
                )
            else:
                toks_dev, self.kv.buffers = self._prefill(
                    self.params,
                    jnp.asarray(tokens),
                    jnp.asarray(plens),
                    self.kv.buffers,
                    jnp.asarray(rows),
                )
            # admission-time sync: one batched fetch per prefill group
            toks = jax.device_get(toks_dev)
        dt = time.perf_counter() - t0
        self.tracer.end(self._tk_prefill, self._nm_prefill, s, nb)
        self.stats.record_host_sync()
        now = time.perf_counter()
        self.stats.record_prefill(
            int(plens.sum()),
            dt,
            emitted=len(states),
            batch=len(states),
            bucket=(nb, s),
        )
        dur_ns = int(dt * 1e9)
        for i, state in enumerate(states):
            state.generated.append(int(toks[i]))
            state.pos = state.plen
            state.first_token_s = now
            self.stats.record_ttft(now - state.request.submit_s)
            # per-slot lifecycle: the prefill interval, then the decode
            # span that stays open until finish (or preemption)
            self.tracer.complete(
                self._tk_slot[state.slot], self._nm_prefill, t0_ns,
                dur_ns, s, nb,
            )
            self.tracer.begin(
                self._tk_slot[state.slot],
                self._nm_decode,
                state.request.uid,
            )
            if self._prefix is not None:
                # index the prompt's full pages (hits refresh their LRU
                # tick; new full pages — suffix included — become
                # matchable the moment their contents are synced)
                self._prefix.insert(
                    state.request.prompt,
                    self.kv.page_table[state.slot],
                )
        return states

    # ---- stepping ----------------------------------------------------
    @hot_path
    def step(self) -> list[FinishedRequest]:
        """One scheduler iteration: admit (batched, possibly after
        preempting) -> resume swapped sequences -> decode -> evict.

        Same-bucket groups are split greedily into power-of-two chunks
        (4 -> one call of 4; 3 -> 2+1) capped at ``max_prefill_batch``:
        every chunk exactly fills its compiled (N, S) program, so batching
        never pays for padded batch rows."""
        finished: list[FinishedRequest] = list(self._rejected)
        self._rejected.clear()
        self._expire_waiting(finished)
        # compile correlation: each phase span carries the backend
        # compiles observed while it ran (a1 of its E event) — 0 after
        # warmup, the DispatchGuard invariant made continuously visible
        c0 = compile_events_total()
        tr = self.tracer
        tr.begin(self._tk_admission, self._nm_admission)
        plan = self._plan_admission()
        if self._maybe_preempt(plan):
            # the resource picture changed: recompute the whole pass so
            # the blocked high-priority request plans first
            self._unplan(plan)
            plan = self._plan_admission()
        n_admitted = len(plan.resumes)
        for req, pages in plan.resumes:
            self._resume(req, pages)
        cap = self.ecfg.max_prefill_batch
        for (s, npre), plans in plan.groups.items():
            i = 0
            while i < len(plans):
                n = 1 << (min(len(plans) - i, cap).bit_length() - 1)
                for state in self._admit_group(plans[i : i + n], s, npre):
                    n_admitted += 1
                    if state.done:  # max_new_tokens == 1 or instant EOS
                        finished.append(self._finish(state))
                i += n
        c1 = compile_events_total()
        tr.end(self._tk_admission, self._nm_admission, n_admitted, c1 - c0)

        # a prompt that already fills its slot cannot take a decode step
        for st_ in list(self.scheduler.active()):
            if st_.pos >= self.ecfg.max_len:
                finished.append(self._finish(st_, reason="capacity"))

        active = self.scheduler.active()
        decode_dt: float | None = None
        if active:
            tokens = np.zeros((self.ecfg.max_slots,), np.int32)
            positions = np.zeros((self.ecfg.max_slots,), np.int32)
            for st_ in active:
                self._ensure_writable(st_.slot, st_.pos)
                self._alloc(st_.slot, st_.pos)
                tokens[st_.slot] = st_.generated[-1]
                positions[st_.slot] = st_.pos
            t0 = time.perf_counter()
            tr.begin(self._tk_decode, self._nm_decode_step, len(active))
            with self.mesh:
                # token picked inside the jit'd step either way: the one
                # host sync fetches (slots,) ids. All-plain traffic takes
                # the argmax variant (zero sampling overhead); any fancy
                # active slot switches the step to the fused sampler.
                if self._fancy_slots:
                    toks_dev, self.kv.buffers, self._presence = (
                        self._decode_sampled(
                            self.params,
                            self.kv.buffers,
                            jnp.asarray(tokens),
                            jnp.asarray(positions),
                            self.kv.device_table(),
                            self._decode_sampler(),
                            self._presence,
                        )
                    )
                else:
                    toks_dev, self.kv.buffers = self._decode(
                        self.params,
                        self.kv.buffers,
                        jnp.asarray(tokens),
                        jnp.asarray(positions),
                        self.kv.device_table(),
                    )
                # THE one sanctioned host sync per decode step: a single
                # batched (slots,) fetch of every active slot's next
                # token. Everything downstream (EOS checks, finish
                # bookkeeping) reads this numpy row, never the device.
                tr.begin(self._tk_sync, self._nm_host_sync)
                nxt = jax.device_get(toks_dev)  # jaxlint: disable=JL001 -- the one batched per-step fetch of the next-token row
                tr.end(self._tk_sync, self._nm_host_sync, len(active))
            dt = decode_dt = time.perf_counter() - t0
            tr.end(
                self._tk_decode,
                self._nm_decode_step,
                len(active),
                compile_events_total() - c1,
            )
            self.stats.record_host_sync()
            self.stats.record_decode_step(
                len(active), self.ecfg.max_slots, dt
            )
            for st_ in active:
                st_.pos += 1
                st_.generated.append(int(nxt[st_.slot]))
                if st_.done:
                    finished.append(self._finish(st_))
                elif st_.pos >= self.ecfg.max_len:
                    finished.append(self._finish(st_, reason="capacity"))
        # the decode step has been overlapping any in-flight swap-out
        # transfers; land them on the host and drop the device staging
        for record in self._pending_swaps:
            self.swap.finalize(record)
        self._pending_swaps.clear()
        step_compiles = compile_events_total() - c0
        self.stats.record_step_compiles(step_compiles)
        self._step_idx += 1
        self._observe_step(decode_dt, len(active), step_compiles)
        return finished

    # ---- live telemetry (end of step, host-side) ---------------------
    def _observe_step(
        self,
        decode_dt: float | None,
        n_active: int,
        step_compiles: int,
    ) -> None:
        """End-of-step observability hook — after the step's one
        sanctioned sync, never inside a jit'd program. With tracing,
        monitoring and the flight recorder all off this is three no-op
        tracer calls and an early return (the NULL tracer makes no
        clock calls — the zero-obs-work invariant the tests assert)."""
        tr = self.tracer
        kv = self.kv
        live = kv.n_pages - kv.free_pages - kv.cached_pages
        tr.counter(self._tk_counters, self._nm_ctr_live, live)
        tr.counter(
            self._tk_counters,
            self._nm_ctr_queue,
            len(self.scheduler.waiting),
        )
        tr.counter(self._tk_counters, self._nm_ctr_running, n_active)
        if self._flight is not None:
            if step_compiles == 0:
                self._flight_armed = True
            elif self._flight_armed:
                # post-warmup compile: the DispatchGuard invariant
                # tripped mid-traffic — snapshot what led up to it
                self._capture_incident(
                    "dispatch_guard_trip",
                    {"step_compiles": step_compiles},
                )
            if self._spike is not None and decode_dt is not None:
                baseline = self._spike.baseline
                if self._spike.observe(decode_dt):
                    self._capture_incident(
                        "step_time_spike",
                        {
                            "decode_step_s": decode_dt,
                            "baseline_s": baseline,
                            "factor": self.ecfg.spike_factor,
                        },
                    )
        if self._window is None:
            return
        self._sample_memory(live)
        with self._obs_lock:
            self._window.tick()
            status = (
                self._slo_mon.evaluate()
                if self._slo_mon is not None
                else None
            )
        if status is None:
            return
        self.stats.record_slo_state(
            status["state_code"], status["fast_burn"], status["slow_burn"]
        )
        if status["state"] == CRITICAL and self.ecfg.slo.shed:
            self._shed_queued(self.ecfg.slo.shed_max_per_tick)
        if status["transitioned_to"] == CRITICAL and self._flight is not None:
            self._capture_incident("slo_critical", {"slo": status})

    def _sample_memory(self, live_pages: int) -> None:
        """Per-step device-memory gauges: pool occupancy/fragmentation,
        COW reserve, host-swap residency, and the backend allocator's
        bytes-in-use where the platform exposes them."""
        host_bytes = sum(
            rec.n_host for _, rec in self._swapped.values()
        ) * self.swap.page_bytes
        dev_bytes = 0
        if self._device_memory_stats is not None:
            try:
                dev_bytes = int(
                    self._device_memory_stats().get("bytes_in_use", 0)
                )
            except Exception:  # backend stopped cooperating: disable
                self._device_memory_stats = None
        self.stats.record_memory(
            n_pages=self.kv.n_pages,
            live_pages=live_pages,
            cached_pages=self.kv.cached_pages,
            reserved_pages=self._reserved_pages(),
            cow_reserve_pages=sum(self._cow_reserve.values()),
            host_swap_bytes=host_bytes,
            device_bytes_in_use=dev_bytes,
        )

    def _shed_queued(self, max_n: int) -> int:
        """CRITICAL-state load shed: reject up to ``max_n`` waiting
        requests from the lowest priority class present, newest-queued
        first (within a class the queue is deadline-then-FCFS ordered,
        so the tail is the least urgent). Swapped-out sequences are
        exempt — they already hold device work and always resume.
        Sheds surface as structured ``REJECT_SHED`` results delivered
        by the next ``step()``, never silent drops."""
        cands = [
            r
            for r in self.scheduler.waiting
            if r.uid not in self._swapped
        ]
        if not cands:
            return 0
        lowest = min(r.schedule.priority for r in cands)
        shed = [r for r in cands if r.schedule.priority == lowest]
        shed = shed[-max_n:][::-1]
        for req in shed:
            self.scheduler.remove(req)
            self._rejected.append(self._reject(req, REJECT_SHED))
        return len(shed)

    def _capture_incident(self, kind: str, context: dict) -> str | None:
        path = self._flight.capture(
            kind,
            tracer=self.tracer,
            metrics=self.metrics,
            config=self._config_dict(),
            context={**context, "step": self._step_idx},
        )
        if path is not None:
            self.stats.record_flight_incident(kind)
        return path

    def _config_dict(self) -> dict:
        e = self.ecfg
        return {
            "max_slots": e.max_slots,
            "max_len": e.max_len,
            "n_pages": self.kv.n_pages,
            "lookahead": e.lookahead,
            "max_prefill_batch": e.max_prefill_batch,
            "prefix_cache": e.prefix_cache,
            "preemption": e.preemption,
            "paged_impl": self.paged_impl,
            "spike_factor": e.spike_factor,
            "slo": dataclasses.asdict(e.slo) if e.slo else None,
        }

    def windowed_vars(self, span_s: float | None = None) -> dict:
        """Live rolling-window stats (the ``/vars`` endpoint). Safe to
        call from the scrape thread: ticks and reads under the obs
        lock, so it never races the step loop's own tick. Percentiles
        come from the window's retained raw samples — a window covering
        the whole run agrees exactly with ``stats_summary()``."""
        if self._window is None:
            return {"enabled": False}
        with self._obs_lock:
            w = self._window
            w.tick()

            def pcts(name: str) -> dict:
                return {
                    f"p{q}_ms": round(
                        w.percentile(name, q, span_s) * 1e3, 3
                    )
                    for q in (50, 95, 99)
                }

            out = {
                "enabled": True,
                "window_s": w.window_s,
                "covered_s": round(w.covered_s, 3),
                "ttft_ms": pcts("repro_serve_ttft_seconds"),
                "queue_wait_ms": pcts("repro_serve_queue_wait_seconds"),
                "token_latency_ms": pcts(
                    "repro_serve_step_latency_seconds"
                ),
                "tok_s": round(
                    w.rate("repro_serve_generated_tokens_total", span_s),
                    2,
                ),
                "admitted_per_s": round(
                    w.rate("repro_serve_prefill_requests_total", span_s),
                    3,
                ),
                "finished_per_s": round(
                    w.rate(
                        "repro_serve_requests_finished_total", span_s
                    ),
                    3,
                ),
                "rejected_per_s": round(
                    w.rate("repro_serve_rejected_total", span_s), 3
                ),
                "queue_depth": len(self.scheduler.waiting),
                "running_slots": len(self.scheduler.active()),
                "memory": {
                    "pool_pages": w.gauge("repro_mem_pool_pages"),
                    "live_pages": w.gauge("repro_mem_pool_live_pages"),
                    "cached_pages": w.gauge(
                        "repro_mem_pool_cached_pages"
                    ),
                    "reserved_pages": w.gauge(
                        "repro_mem_pool_reserved_pages"
                    ),
                    "fragmentation": w.gauge(
                        "repro_mem_pool_fragmentation_ratio"
                    ),
                    "host_swap_bytes": w.gauge(
                        "repro_mem_host_swap_bytes"
                    ),
                    "device_bytes_in_use": w.gauge(
                        "repro_mem_device_bytes_in_use"
                    ),
                },
            }
            if self._slo_mon is not None:
                out["slo"] = dict(self._slo_mon.last)
            return out

    def window_samples(
        self, name: str, span_s: float | None = None
    ) -> list[float]:
        """Raw window samples for one histogram, read under the obs
        lock (``ReplicaRouter`` merges these for fleet percentiles)."""
        if self._window is None:
            return []
        with self._obs_lock:
            self._window.tick()
            return self._window.samples(name, span_s)

    def slo_state(self) -> dict:
        """Read-only burn-rate status (the ``/slo`` endpoint). The step
        loop is the only *evaluator* — a scrape returns the retained
        ``last`` result and can never consume a state transition."""
        if self._slo_mon is None:
            return {"enabled": False}
        return {"enabled": True, **self._slo_mon.last}

    def roofline(self) -> dict:
        """Roofline terms for the compiled decode step (lazy, cached
        per engine): FLOPs and HBM bytes parsed from the optimized HLO
        (``repro.analysis.roofline``), per-device time lower bounds and
        the dominant bottleneck. Costs one extra AOT compile of the
        decode program on first call; degrades to ``available: False``
        zeros when the backend can't produce analyzable HLO."""
        if self._roofline is not None:
            return self._roofline
        try:
            from repro.analysis.roofline import analyze_hlo, roofline_terms

            zeros = jnp.zeros((self.ecfg.max_slots,), jnp.int32)
            table0 = jnp.zeros_like(jnp.asarray(self.kv.page_table))
            with self.mesh:
                txt = (
                    self._decode.lower(
                        self.params, self.kv.buffers, zeros, zeros, table0
                    )
                    .compile()
                    .as_text()
                )
            cost = analyze_hlo(txt)
            terms = roofline_terms(cost)
            self._roofline = {
                "available": True,
                "flops": cost.flops,
                "bytes_accessed": cost.bytes_accessed,
                "collective_bytes": cost.total_collective_bytes,
                "arithmetic_intensity": round(
                    cost.flops / cost.bytes_accessed, 4
                )
                if cost.bytes_accessed
                else 0.0,
                "bottleneck": terms["bottleneck"],
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "collective_s": terms["collective_s"],
            }
            # annotate the trace: a0 = intensity x1000, a1 = bottleneck
            self.tracer.instant(
                self._tk_decode,
                self._nm_roofline,
                int(self._roofline["arithmetic_intensity"] * 1000),
                {"compute": 0, "memory": 1, "collective": 2}.get(
                    terms["bottleneck"], 3
                ),
            )
        except Exception:
            self._roofline = {
                "available": False,
                "flops": 0.0,
                "bytes_accessed": 0.0,
                "collective_bytes": 0.0,
                "arithmetic_intensity": 0.0,
                "bottleneck": "unknown",
                "compute_s": 0.0,
                "memory_s": 0.0,
                "collective_s": 0.0,
            }
        return self._roofline

    def _finish(
        self, state: SequenceState, *, reason: str | None = None
    ) -> FinishedRequest:
        # Early-finish reclamation: pages the lifetime budget reserved
        # but the sequence never touched (EOS before max_new_tokens) go
        # straight back to the admission budget — popping the need entry
        # releases the reservation, freeing the slot returns the
        # allocated pages — and are counted for the stats.
        need = self._page_need.pop(state.slot, 0)
        self._cow_reserve.pop(state.slot, None)
        reclaimed = max(0, need - self.kv.pages_owned(state.slot))
        # close the slot's decode span (opened at admission/resume)
        self.tracer.end(
            self._tk_slot[state.slot],
            self._nm_decode,
            state.request.uid,
            len(state.generated),
        )
        self.tracer.instant(
            self._tk_slot[state.slot], self._nm_finished, state.request.uid
        )
        if self._prefix is not None:
            # index the decode-written pages too (full blocks only): the
            # next turn of a multi-turn conversation prompts with this
            # sequence's history and hits these pages. The last generated
            # token is returned but never written back, so the indexed
            # content is prompt + generated[:-1].
            written = np.concatenate(
                [
                    state.request.prompt,
                    np.asarray(state.generated[:-1], np.int32),
                ]
            )
            self.stats.record_decode_indexed(
                self._prefix.insert(
                    written, self.kv.page_table[state.slot]
                )
            )
        self.scheduler.evict(state.slot)
        # radix-indexed pages are parked (refcount 0, device-resident)
        # instead of freed: a future prompt sharing the prefix maps them
        # straight back in, and eviction reclaims them on demand
        self.kv.free_slot(
            state.slot,
            keep=None if self._prefix is None else self._prefix.page_in_tree,
        )
        self._fancy_slots.discard(state.slot)
        if reclaimed:
            self.stats.record_reclaimed(reclaimed)
        if reason is None:
            eos = state.request.eos_id
            reason = (
                "eos"
                if eos is not None and state.generated[-1] == eos
                else "length"
            )
        now = time.perf_counter()
        req = state.request
        fin = FinishedRequest(
            uid=req.uid,
            prompt=req.prompt,
            tokens=np.asarray(state.generated, np.int32),
            finish_reason=reason,
            admit_step=state.admit_step,
            finish_step=self._step_idx,
            prefix_hit_tokens=state.prefix_hit_tokens,
            preemptions=state.preemptions,
            ttft_s=(
                state.first_token_s - req.submit_s
                if state.first_token_s is not None
                else None
            ),
            e2e_s=now - req.submit_s,
            schedule=req.schedule,
        )
        self.stats.record_finish(
            kind=req.sampling.kind,
            tokens=len(state.generated),
            slo_met=fin.slo_met,
        )
        return fin

    def drain(self, max_steps: int | None = None) -> list[FinishedRequest]:
        """Step until every submitted request has finished (including
        structured rejections awaiting delivery)."""
        out: list[FinishedRequest] = []
        steps = 0
        while not self.scheduler.idle or self._rejected:
            out.extend(self.step())
            steps += 1
            if (
                max_steps is not None
                and steps >= max_steps
                and not self.scheduler.idle
            ):
                raise RuntimeError(
                    f"drain did not converge in {max_steps} steps"
                )
        return out

    def reset_stats(self) -> None:
        """Zero the per-run counters (benchmark repeats); the radix
        tree's contents survive — only the numbers reset.

        The metrics registry and the tracer ring reset *atomically*
        (both or neither): a fresh registry is built and every stats
        view rebinds to it, and the tracer closes any open spans (they
        are counted as truncated, and their pending ``end()`` calls
        become no-ops) before clearing its ring — a mid-traffic reset
        never leaks a dangling span into the next export."""
        reg = MetricsRegistry()
        self.stats = ServeStats(reg)
        self.swap.stats = SwapStats(reg)
        if self._prefix is not None:
            self._prefix.stats = PrefixStats(reg)
        self.metrics = reg
        self.tracer.reset()

    def stats_summary(self) -> dict:
        out = self.stats.summary()
        out["preemption"].update(self.swap.stats.snapshot())
        if self._prefix is not None:
            out["prefix_cache"].update(self._prefix.stats.snapshot())
            out["prefix_cache"]["enabled"] = True
            out["prefix_cache"]["cached_pages"] = self.kv.cached_pages
            # keep the prom gauge in step with the pool
            self._prefix.stats.set_cached_pages(self.kv.cached_pages)
        out["roofline"] = self.roofline()
        return out

    def export_perfetto(self, path: str) -> int:
        """Write this engine's trace ring as Chrome trace-event JSON
        (requires ``EngineConfig(trace=...)``)."""
        return self.tracer.export_perfetto(path)
