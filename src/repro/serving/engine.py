"""Continuous-batching serving engine over the block-paged KV cache.

``Engine.submit()`` enqueues requests; each ``step()`` admits whatever
fits (bucketed jit'd prefill straight into the paged cache — no per-token
prefill loop), runs ONE jit'd decode step over all slots (ragged per-slot
positions, idle slots masked to the trash page), and evicts finished
sequences so their slot and pages are reusable the very next step.
``drain()`` loops until the queue and slots are empty.

The decode step is always shaped ``(max_slots,)`` and prefill shapes are
bucketed to power-of-two page counts, so the engine compiles a handful of
programs total no matter how ragged the traffic is.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import transformer as T
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import FinishedRequest, Request, SequenceState
from repro.serving.scheduler import Scheduler
from repro.serving.stats import ServeStats

__all__ = ["Engine", "EngineConfig"]


class EngineConfig:
    """Serving knobs: ``max_slots`` concurrent sequences, each with
    ``max_len`` tokens of page-granular KV capacity."""

    def __init__(self, max_slots: int = 8, max_len: int = 512):
        self.max_slots = max_slots
        self.max_len = max_len

    def rounded(self, page: int) -> "EngineConfig":
        max_len = -(-self.max_len // page) * page
        return EngineConfig(self.max_slots, max_len)


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 1 else 1


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        *,
        engine_cfg: EngineConfig | None = None,
        strategy: str = "fsdp",
        seed: int = 0,
        params=None,
        paged_impl: str | None = None,
    ):
        """``paged_impl`` selects the paged decode-attention read:
        "gather" (portable jnp reference), "pallas" (fused page-pool
        TPU kernel), or "interpret" (the kernel body interpreted, for
        validation). None picks per platform like ``kernels.ops``."""
        self.mesh = mesh
        st = sharding.Strategy(mesh, strategy)
        self.cfg = cfg = cfg.replace(tp_size=st.tp_size, batch_axes=st.batch)
        self.st = st
        ecfg = (engine_cfg or EngineConfig()).rounded(cfg.attn_block)
        self.ecfg = ecfg
        with mesh:
            if params is None:
                key = jax.random.PRNGKey(seed)
                pshape = jax.eval_shape(lambda k: T.init_model(k, cfg), key)
                psh = sharding.param_shardings(st, pshape)
                params = jax.jit(
                    lambda k: T.init_model(k, cfg), out_shardings=psh
                )(key)
            self.params = params
            self.kv = PagedKVCache(cfg, ecfg.max_slots, ecfg.max_len)
            if paged_impl is None:
                from repro.kernels.ops import default_impl

                paged_impl = (
                    "pallas" if default_impl() == "pallas" else "gather"
                )
            if paged_impl not in ("gather", "pallas", "interpret"):
                raise ValueError(
                    f"unknown paged_impl {paged_impl!r}; expected "
                    "'gather', 'pallas' or 'interpret'"
                )
            self.paged_impl = paged_impl
            self._decode = jax.jit(
                lambda p, c, t, pos, pt: T.decode_step_paged(
                    cfg, p, c, t, pos, pt, paged_impl=paged_impl
                ),
                donate_argnums=(1,),
            )
            # one wrapper; jax.jit specializes per (1, S) bucket shape
            self._prefill = jax.jit(
                lambda p, t, plen, c, row: T.prefill_paged(
                    cfg, p, t, plen, c, row
                ),
                donate_argnums=(3,),
            )
        self.scheduler = Scheduler(ecfg.max_slots)
        self.stats = ServeStats()
        self._uid = 0
        self._step_idx = 0

    # ---- request intake ----------------------------------------------
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        *,
        eos_id: int | None = None,
    ) -> int:
        """Enqueue one request; returns its uid."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size > self.ecfg.max_len:
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds max_len "
                f"{self.ecfg.max_len}"
            )
        self._uid += 1
        self.scheduler.submit(
            Request(self._uid, prompt, max_new_tokens, eos_id=eos_id)
        )
        return self._uid

    # ---- prefill -----------------------------------------------------
    def _bucket(self, plen: int) -> int:
        """Pad prompt lengths to power-of-two page counts: a handful of
        compiled prefill programs serve every prompt length."""
        nb = min(
            _next_pow2(self.kv.pages_for_len(plen)), self.kv.pages_per_seq
        )
        return nb * self.kv.page

    def _admit_one(self) -> SequenceState | None:
        req = self.scheduler.peek_waiting()
        if req is None or self.scheduler.free_slot() is None:
            return None
        s = self._bucket(req.prompt.size)
        if self.kv.pages_for_len(s) > self.kv.free_pages:
            return None  # admit once pages free up
        state = self.scheduler.admit(self._step_idx)
        assert state is not None
        plen = state.plen
        self.kv.alloc_upto(state.slot, s - 1)
        row = jnp.asarray(self.kv.table_row(state.slot, s // self.kv.page))
        tokens = np.zeros((1, s), np.int32)
        tokens[0, :plen] = state.request.prompt
        t0 = time.perf_counter()
        with self.mesh:
            logits, self.kv.buffers = self._prefill(
                self.params,
                jnp.asarray(tokens),
                jnp.asarray(plen, jnp.int32),
                self.kv.buffers,
                row,
            )
            tok = int(jax.block_until_ready(jnp.argmax(logits)))
        self.stats.record_prefill(plen, time.perf_counter() - t0, emitted=1)
        state.generated.append(tok)
        state.pos = plen
        return state

    # ---- stepping ----------------------------------------------------
    def step(self) -> list[FinishedRequest]:
        """One scheduler iteration: admit -> decode -> evict."""
        finished: list[FinishedRequest] = []
        while True:
            state = self._admit_one()
            if state is None:
                break
            if state.done:  # max_new_tokens == 1 or instant EOS
                finished.append(self._finish(state))

        # a prompt that already fills its slot cannot take a decode step
        for st_ in list(self.scheduler.active()):
            if st_.pos >= self.ecfg.max_len:
                finished.append(self._finish(st_, reason="capacity"))

        active = self.scheduler.active()
        if active:
            tokens = np.zeros((self.ecfg.max_slots,), np.int32)
            positions = np.zeros((self.ecfg.max_slots,), np.int32)
            for st_ in active:
                self.kv.alloc_upto(st_.slot, st_.pos)
                tokens[st_.slot] = st_.generated[-1]
                positions[st_.slot] = st_.pos
            t0 = time.perf_counter()
            with self.mesh:
                logits, self.kv.buffers = self._decode(
                    self.params,
                    self.kv.buffers,
                    jnp.asarray(tokens),
                    jnp.asarray(positions),
                    jnp.asarray(self.kv.page_table),
                )
                nxt = np.asarray(
                    jax.block_until_ready(jnp.argmax(logits, axis=-1))
                )
            dt = time.perf_counter() - t0
            self.stats.record_decode_step(
                len(active), self.ecfg.max_slots, dt
            )
            for st_ in active:
                st_.pos += 1
                st_.generated.append(int(nxt[st_.slot]))
                if st_.done:
                    finished.append(self._finish(st_))
                elif st_.pos >= self.ecfg.max_len:
                    finished.append(self._finish(st_, reason="capacity"))
        self._step_idx += 1
        return finished

    def _finish(
        self, state: SequenceState, *, reason: str | None = None
    ) -> FinishedRequest:
        self.scheduler.evict(state.slot)
        self.kv.free_slot(state.slot)
        self.stats.record_finish()
        if reason is None:
            eos = state.request.eos_id
            reason = (
                "eos"
                if eos is not None and state.generated[-1] == eos
                else "length"
            )
        return FinishedRequest(
            uid=state.request.uid,
            prompt=state.request.prompt,
            tokens=np.asarray(state.generated, np.int32),
            finish_reason=reason,
            admit_step=state.admit_step,
            finish_step=self._step_idx,
        )

    def drain(self, max_steps: int | None = None) -> list[FinishedRequest]:
        """Step until every submitted request has finished."""
        out: list[FinishedRequest] = []
        steps = 0
        while not self.scheduler.idle:
            out.extend(self.step())
            steps += 1
            if (
                max_steps is not None
                and steps >= max_steps
                and not self.scheduler.idle
            ):
                raise RuntimeError(
                    f"drain did not converge in {max_steps} steps"
                )
        return out

    def stats_summary(self) -> dict:
        return self.stats.summary()
