"""Data-parallel replica routing: one logical engine over N replicas.

Tensor parallelism shards a single engine's weights and KV pools over
the ``model`` mesh axis (``PagedKVCache(strategy=)``); *data*
parallelism for serving traffic is a different shape entirely — requests
are independent, so the right construction is N complete engines on
disjoint device slices with a thin router in front, not a batch-sharded
step. A batch-sharded decode would force every replica to run in
lockstep with the slowest admission wave; independent engines admit,
preempt and finish on their own clocks.

``ReplicaRouter`` exposes the ``Engine`` surface (``submit`` /
``step`` / ``drain``) and routes each request to the least-loaded
replica (outstanding-request count, ties to the lowest index, so
single-request traffic is deterministic). Streams are bit-identical to
any single engine's: every replica initializes the same parameters from
the same seed, and the sampler's noise is keyed on (request seed,
sample index) — never on the slot, batch or device that serves it.

Router uids are replica-independent: ``submit`` returns a router-level
uid and finished results are re-tagged with it, so callers never see
replica-local ids.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.obs import MetricsRegistry, export_perfetto
from repro.serving.engine import Engine, EngineConfig
from repro.serving.prefix import PrefixStats
from repro.serving.request import FinishedRequest, ScheduleParams
from repro.serving.sampling import SamplingParams
from repro.serving.stats import ServeStats
from repro.serving.swap import SwapStats

__all__ = ["ReplicaRouter"]


class ReplicaRouter:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        replicas: int,
        tp: int = 1,
        engine_cfg: EngineConfig | None = None,
        strategy: str = "tp",
        seed: int = 0,
        paged_impl: str | None = None,
        devices: list | None = None,
    ):
        """``replicas * tp`` devices are carved into ``replicas``
        disjoint ``(1, tp)`` meshes (axes ``("data", "model")``), one
        full engine per slice. ``tp > 1`` composes both parallelism
        kinds: each replica is itself tensor-parallel."""
        if replicas < 1 or tp < 1:
            raise ValueError("replicas and tp must be >= 1")
        devices = list(devices if devices is not None else jax.devices())
        need = replicas * tp
        if len(devices) < need:
            raise ValueError(
                f"{replicas} replicas x tp={tp} needs {need} devices, "
                f"have {len(devices)}"
            )
        self.replicas = replicas
        self.tp = tp
        self.engines: list[Engine] = []
        for r in range(replicas):
            sub = np.asarray(devices[r * tp : (r + 1) * tp]).reshape(1, tp)
            mesh = Mesh(sub, ("data", "model"))
            self.engines.append(
                Engine(
                    cfg,
                    mesh,
                    engine_cfg=engine_cfg,
                    strategy=strategy,
                    seed=seed,
                    paged_impl=paged_impl,
                )
            )
        self._outstanding = [0] * replicas
        # router uid -> (replica, replica-local uid); local uid -> router
        self._placed: dict[int, tuple[int, int]] = {}
        self._router_uid: list[dict[int, int]] = [{} for _ in range(replicas)]
        self._uid = 0

    # ---- request intake ----------------------------------------------
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        *,
        eos_id: int | None = None,
        sampling: SamplingParams | None = None,
        schedule: ScheduleParams | None = None,
    ) -> int:
        """Enqueue on the least-loaded replica; returns a router uid."""
        r = min(range(self.replicas), key=lambda i: (self._outstanding[i], i))
        local = self.engines[r].submit(
            prompt,
            max_new_tokens,
            eos_id=eos_id,
            sampling=sampling,
            schedule=schedule,
        )
        self._uid += 1
        self._outstanding[r] += 1
        self._placed[self._uid] = (r, local)
        self._router_uid[r][local] = self._uid
        return self._uid

    # ---- stepping ----------------------------------------------------
    def step(self) -> list[FinishedRequest]:
        """Step every replica once; finished results carry router uids."""
        out: list[FinishedRequest] = []
        for r, eng in enumerate(self.engines):
            for fin in eng.step():
                uid = self._router_uid[r].pop(fin.uid, fin.uid)
                self._placed.pop(uid, None)
                self._outstanding[r] -= 1
                out.append(dataclasses.replace(fin, uid=uid))
        return out

    @property
    def idle(self) -> bool:
        return all(n == 0 for n in self._outstanding)

    def drain(self, max_steps: int | None = None) -> list[FinishedRequest]:
        out: list[FinishedRequest] = []
        steps = 0
        while not self.idle:
            out.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps and not self.idle:
                raise RuntimeError(
                    f"drain did not converge in {max_steps} steps"
                )
        return out

    # ---- observability ------------------------------------------------
    def merged_metrics(self) -> MetricsRegistry:
        """One registry over every replica: counters and gauges sum,
        histogram samples concatenate — merged percentiles are true
        fleet percentiles, not averages of averages."""
        for eng in self.engines:
            if eng._prefix is not None:
                eng._prefix.stats.set_cached_pages(eng.kv.cached_pages)
        return MetricsRegistry.merged([eng.metrics for eng in self.engines])

    def stats_summary(self) -> dict:
        """Fleet-level ``Engine.stats_summary()``: the same schema
        computed over the merged registry, plus a ``per_replica``
        breakdown (each replica's own full summary)."""
        merged = self.merged_metrics()
        # stats views bind to the merged registry's existing metrics
        # (get-or-create), so this is the engine summary over fleet data
        out = ServeStats(merged).summary()
        out["preemption"].update(SwapStats(merged).snapshot())
        if any(eng._prefix is not None for eng in self.engines):
            out["prefix_cache"].update(PrefixStats(merged).snapshot())
            out["prefix_cache"]["enabled"] = True
            out["prefix_cache"]["cached_pages"] = sum(
                eng.kv.cached_pages
                for eng in self.engines
                if eng._prefix is not None
            )
        # every replica compiles the same decode program; engine 0's
        # roofline stands for the fleet (per_replica carries the rest)
        out["roofline"] = self.engines[0].roofline()
        out["per_replica"] = [eng.stats_summary() for eng in self.engines]
        return out

    def windowed_vars(self, span_s: float | None = None) -> dict:
        """Fleet ``/vars``: true merged percentiles over every
        replica's retained window samples (concatenation, same policy
        as ``merged_metrics`` — never an average of averages), summed
        rates and depths, plus each replica's own view."""
        per = [eng.windowed_vars(span_s) for eng in self.engines]
        live = [p for p in per if p.get("enabled")]
        if not live:
            return {"enabled": False, "per_replica": per}

        def pcts(name: str) -> dict:
            s: list[float] = []
            for eng in self.engines:
                s.extend(eng.window_samples(name, span_s))
            if not s:
                return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}
            arr = np.asarray(s, np.float64)
            return {
                f"p{q}_ms": round(
                    float(np.percentile(arr, q)) * 1e3, 3
                )
                for q in (50, 95, 99)
            }

        return {
            "enabled": True,
            "replicas": self.replicas,
            "window_s": max(p["window_s"] for p in live),
            "covered_s": max(p["covered_s"] for p in live),
            "ttft_ms": pcts("repro_serve_ttft_seconds"),
            "queue_wait_ms": pcts("repro_serve_queue_wait_seconds"),
            "token_latency_ms": pcts("repro_serve_step_latency_seconds"),
            "tok_s": round(sum(p["tok_s"] for p in live), 2),
            "admitted_per_s": round(
                sum(p["admitted_per_s"] for p in live), 3
            ),
            "finished_per_s": round(
                sum(p["finished_per_s"] for p in live), 3
            ),
            "rejected_per_s": round(
                sum(p["rejected_per_s"] for p in live), 3
            ),
            "queue_depth": sum(p["queue_depth"] for p in live),
            "running_slots": sum(p["running_slots"] for p in live),
            "per_replica": per,
        }

    def slo_state(self) -> dict:
        """Fleet ``/slo``: the worst replica's state fronts the
        response (an alert on any replica is an alert on the service)."""
        per = [eng.slo_state() for eng in self.engines]
        live = [p for p in per if p.get("enabled")]
        if not live:
            return {"enabled": False, "per_replica": per}
        worst = max(live, key=lambda p: p.get("state_code", 0))
        return {
            "enabled": True,
            "state": worst.get("state", "OK"),
            "state_code": worst.get("state_code", 0),
            "per_replica": per,
        }

    def reset_stats(self) -> None:
        for eng in self.engines:
            eng.reset_stats()

    def export_perfetto(self, path: str) -> int:
        """One Chrome trace file over every replica: replica r's tracks
        appear under process ``pid=r``."""
        return export_perfetto(
            {r: eng.tracer for r, eng in enumerate(self.engines)}, path
        )
