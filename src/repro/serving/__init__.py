"""Continuous-batching serving engine with a block-paged KV cache.

The cache page size equals the attention block size
(``ModelConfig.attn_block``), so the pixelfly block-sparse decode
schedule maps one-to-one onto cache pages: each token reads only the
pages its local/butterfly/global schedule visits.

  from repro.serving import Engine, EngineConfig
  eng = Engine(cfg, mesh, engine_cfg=EngineConfig(max_slots=8, max_len=512))
  eng.submit(prompt_tokens, max_new_tokens=32)
  finished = eng.drain()
  print(eng.stats_summary())
"""

from repro.serving.engine import Engine, EngineConfig
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix import PrefixCache
from repro.serving.request import (
    REJECT_TIMEOUT,
    REJECT_TOO_LARGE,
    FinishedRequest,
    Request,
    ScheduleParams,
    SequenceState,
)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler
from repro.serving.stats import ServeStats
from repro.serving.swap import SwapManager

__all__ = [
    "Engine",
    "EngineConfig",
    "PagedKVCache",
    "PrefixCache",
    "Request",
    "SamplingParams",
    "ScheduleParams",
    "SequenceState",
    "FinishedRequest",
    "REJECT_TOO_LARGE",
    "REJECT_TIMEOUT",
    "Scheduler",
    "ServeStats",
    "SwapManager",
]
