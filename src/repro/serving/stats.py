"""Latency / throughput / occupancy tracking for the serving engine.

Everything is recorded host-side per engine step into a typed
:class:`repro.obs.MetricsRegistry` — ``ServeStats`` is a *view* over
the registry, not a bag of ad-hoc ints.  The same registry therefore
feeds two consumers that must never disagree:

  * ``summary()`` — the benchmark-facing dict.  Its schema and values
    are identical to the pre-registry implementation (integer counters
    stay ints, percentiles are computed from the raw histogram samples
    with ``np.percentile``), so BENCH trajectories don't move.
  * ``repro.obs.prom.render`` — the Prometheus text exposition of the
    same counters/gauges/histograms.

Passing an existing registry binds to its metrics (get-or-create), so
``ReplicaRouter`` builds a merged summary by constructing a ``ServeStats``
view over ``MetricsRegistry.merged(per_replica_registries)``.
"""

from __future__ import annotations

import numpy as np

from repro.obs import MetricsRegistry

__all__ = ["ServeStats"]


class ServeStats:
    def __init__(self, registry: MetricsRegistry | None = None):
        reg = registry if registry is not None else MetricsRegistry()
        self.registry = reg
        c, g, h = reg.counter, reg.gauge, reg.histogram
        self._prefills = c(
            "repro_serve_prefill_calls_total", "jit'd prefill calls"
        )
        self._prefill_requests = c(
            "repro_serve_prefill_requests_total",
            "requests admitted via prefill",
        )
        self._prefill_tokens = c(
            "repro_serve_prefill_tokens_total", "prompt tokens prefilled"
        )
        self._prefill_time = c(
            "repro_serve_prefill_seconds_total", "wall seconds in prefill"
        )
        # (N_bucket, S_bucket) label -> how well batched admission packs
        # each compiled prefill program
        self._bucket_calls = c(
            "repro_serve_prefill_bucket_calls_total",
            "prefill calls per compiled (N, S) bucket",
            labelname="bucket",
        )
        self._bucket_requests = c(
            "repro_serve_prefill_bucket_requests_total",
            "requests admitted per compiled (N, S) bucket",
            labelname="bucket",
        )
        self._decode_time = c(
            "repro_serve_decode_seconds_total", "wall seconds in decode"
        )
        self._decode_steps = c(
            "repro_serve_decode_steps_total", "decode steps"
        )
        self._decode_tokens = c(
            "repro_serve_decode_tokens_total",
            "tokens emitted by decode steps (excludes prefill-emitted)",
        )
        self._generated = c(
            "repro_serve_generated_tokens_total", "all emitted tokens"
        )
        self._step_latency = h(
            "repro_serve_step_latency_seconds", "decode step wall time"
        )
        self._occupancy = h(
            "repro_serve_occupancy_ratio",
            "active slots / max slots per decode step",
            buckets=tuple(2.0 ** -k for k in range(6, 0, -1)) + (1.0,),
        )
        self._finished = c(
            "repro_serve_requests_finished_total", "requests finished"
        )
        # sampler kind (SamplingParams.kind, e.g. "greedy",
        # "temperature+top_k") -> finished requests / emitted tokens
        self._finished_by_sampler = c(
            "repro_serve_finished_by_sampler_total",
            "finished requests per sampler kind",
            labelname="sampler",
        )
        self._tokens_by_sampler = c(
            "repro_serve_tokens_by_sampler_total",
            "emitted tokens per sampler kind",
            labelname="sampler",
        )
        # lifetime-budgeted pages handed back unused because a sequence
        # finished (EOS) before its reservation ran out
        self._pages_reclaimed = c(
            "repro_serve_pages_reclaimed_early_total",
            "reservation pages returned early at finish",
        )
        # prefix cache: prompt tokens served from cached pages vs
        # submitted, shared-page hits, and copy-on-write page splits
        self._prefix_lookups = c(
            "repro_serve_prefix_lookups_total", "radix-tree lookups"
        )
        self._prefix_hit_tokens = c(
            "repro_serve_prefix_hit_tokens_total",
            "prompt tokens served from cached pages",
        )
        self._prefix_prompt_tokens = c(
            "repro_serve_prefix_prompt_tokens_total",
            "prompt tokens submitted through prefix lookup",
        )
        self._prefix_hit_pages = c(
            "repro_serve_prefix_hit_pages_total", "shared pages adopted"
        )
        self._cow_copies = c(
            "repro_serve_cow_copies_total", "copy-on-write page splits"
        )
        # decode-written pages indexed into the radix tree at finish
        # (multi-turn reuse: turn 2's prompt hits turn 1's answer)
        self._decode_indexed = c(
            "repro_serve_decode_indexed_pages_total",
            "decode-written pages indexed at finish",
        )
        # scheduling: preemptions (swap-outs), resumes (swap-ins),
        # structured rejections by reason, SLO attainment for
        # deadline'd requests, and wall-clock TTFT samples
        self._preemptions = c(
            "repro_serve_preemptions_total", "sequences swapped out"
        )
        self._resumes = c(
            "repro_serve_resumes_total", "sequences swapped back in"
        )
        self._rejected = c(
            "repro_serve_rejected_total",
            "structured rejections",
            labelname="reason",
        )
        self._slo_total = c(
            "repro_serve_slo_requests_total", "requests with a deadline"
        )
        self._slo_met = c(
            "repro_serve_slo_met_total", "deadline'd requests that met it"
        )
        self._ttft = h(
            "repro_serve_ttft_seconds", "submit -> first-token wall time"
        )
        self._queue_wait = h(
            "repro_serve_queue_wait_seconds", "submit -> admission wall time"
        )
        # DispatchGuard correlation: compiles observed during engine
        # steps after warmup, and sanctioned explicit host syncs
        self._step_compiles = c(
            "repro_serve_step_compiles_total",
            "backend compiles observed during engine steps",
        )
        self._host_syncs = c(
            "repro_serve_host_syncs_total",
            "sanctioned explicit device->host syncs",
        )
        # device-memory telemetry, sampled host-side at end of step
        # (never inside a jit'd function): paged-pool occupancy and
        # fragmentation, COW reserve, host-swap residency, and the
        # backend allocator's view when the platform exposes one
        self._mem_pool_pages = g(
            "repro_mem_pool_pages", "paged-pool capacity in pages"
        )
        self._mem_live_pages = g(
            "repro_mem_pool_live_pages", "pages mapped to live sequences"
        )
        self._mem_cached_pages = g(
            "repro_mem_pool_cached_pages",
            "pages held by the radix prefix cache (reclaimable)",
        )
        self._mem_reserved_pages = g(
            "repro_mem_pool_reserved_pages",
            "pages reserved for admitted sequences' lifetime budgets",
        )
        self._mem_cow_reserve_pages = g(
            "repro_mem_cow_reserve_pages",
            "pages reserved against pending copy-on-write splits",
        )
        self._mem_fragmentation = g(
            "repro_mem_pool_fragmentation_ratio",
            "1 - free/(free+reclaimable) headroom actually admittable",
        )
        self._mem_host_swap_bytes = g(
            "repro_mem_host_swap_bytes",
            "bytes of swapped-out sequences resident in host memory",
        )
        self._mem_device_bytes_in_use = g(
            "repro_mem_device_bytes_in_use",
            "backend allocator bytes in use (0 when unavailable)",
        )
        # live SLO burn-rate monitor state (0=OK 1=WARN 2=CRITICAL) and
        # the burn rates it derived them from; flight-recorder bundles
        self._slo_state = g(
            "repro_slo_state", "burn-rate monitor state (0/1/2)"
        )
        self._slo_burn_fast = g(
            "repro_slo_burn_rate_fast", "fast-window burn rate"
        )
        self._slo_burn_slow = g(
            "repro_slo_burn_rate_slow", "slow-window burn rate"
        )
        self._flight_incidents = c(
            "repro_flight_incidents_total",
            "flight-recorder incident bundles written",
            labelname="kind",
        )

    # ---- attribute views (external readers + tests) -------------------
    @property
    def prefills(self) -> int:
        return self._prefills.value

    @property
    def prefill_requests(self) -> int:
        return self._prefill_requests.value

    @property
    def prefill_tokens(self) -> int:
        return self._prefill_tokens.value

    @property
    def prefill_time(self) -> float:
        return self._prefill_time.value

    @property
    def prefill_buckets(self) -> dict[tuple[int, int], list[int]]:
        return {
            key: [calls, self._bucket_requests.get(key)]
            for key, calls in self._bucket_calls.items()
        }

    @property
    def decode_time(self) -> float:
        return self._decode_time.value

    @property
    def decode_steps(self) -> int:
        return self._decode_steps.value

    @property
    def generated(self) -> int:
        return self._generated.value

    @property
    def finished(self) -> int:
        return self._finished.value

    @property
    def by_sampler(self) -> dict[str, list[int]]:
        return {
            kind: [n, self._tokens_by_sampler.get(kind)]
            for kind, n in self._finished_by_sampler.items()
        }

    @property
    def pages_reclaimed_early(self) -> int:
        return self._pages_reclaimed.value

    @property
    def prefix_hit_tokens(self) -> int:
        return self._prefix_hit_tokens.value

    @property
    def prefix_prompt_tokens(self) -> int:
        return self._prefix_prompt_tokens.value

    @property
    def prefix_hit_pages(self) -> int:
        return self._prefix_hit_pages.value

    @property
    def prefix_lookups(self) -> int:
        return self._prefix_lookups.value

    @property
    def cow_copies(self) -> int:
        return self._cow_copies.value

    @property
    def decode_indexed_pages(self) -> int:
        return self._decode_indexed.value

    @property
    def preemptions(self) -> int:
        return self._preemptions.value

    @property
    def resumes(self) -> int:
        return self._resumes.value

    @property
    def rejected(self) -> dict[str, int]:
        return dict(self._rejected.items())

    @property
    def slo_total(self) -> int:
        return self._slo_total.value

    @property
    def slo_met(self) -> int:
        return self._slo_met.value

    # ---- recording ---------------------------------------------------
    def record_prefill(
        self,
        n_tokens: int,
        dt: float,
        emitted: int = 0,
        *,
        batch: int = 1,
        bucket: tuple[int, int] | None = None,
    ) -> None:
        """One jit'd prefill *call* admitting ``batch`` requests at once.
        ``emitted``: tokens generated by this prefill (the argmax of each
        request's last-prompt-token logits is its first output);
        ``bucket``: the compiled (N, S) program shape the call ran under.
        """
        self._prefills.inc()
        self._prefill_requests.inc(batch)
        self._prefill_tokens.inc(n_tokens)
        self._prefill_time.inc(dt)
        self._generated.inc(emitted)
        if bucket is not None:
            self._bucket_calls.inc(1, label=tuple(bucket))
            self._bucket_requests.inc(batch, label=tuple(bucket))

    def record_decode_step(
        self, n_active: int, max_slots: int, dt: float
    ) -> None:
        """A decode step emits one token per active slot."""
        self._decode_steps.inc()
        self._decode_time.inc(dt)
        self._generated.inc(n_active)
        self._decode_tokens.inc(n_active)
        self._step_latency.observe(dt)
        self._occupancy.observe(n_active / max_slots)

    def record_finish(
        self,
        n: int = 1,
        *,
        kind: str | None = None,
        tokens: int = 0,
        slo_met: bool | None = None,
    ) -> None:
        self._finished.inc(n)
        if kind is not None:
            self._finished_by_sampler.inc(n, label=kind)
            self._tokens_by_sampler.inc(tokens, label=kind)
        if slo_met is not None:  # the request carried a deadline
            self._slo_total.inc()
            self._slo_met.inc(int(bool(slo_met)))

    def record_reject(self, reason: str, *, had_deadline: bool = False) -> None:
        """A structured rejection (never admitted): too-large geometry
        or queue-wait timeout. A rejected deadline'd request counts as
        an SLO miss (it can never meet its deadline)."""
        self._rejected.inc(1, label=reason)
        if had_deadline:
            self._slo_total.inc()

    def record_preemption(self, n: int = 1) -> None:
        """A running sequence was swapped out to host memory."""
        self._preemptions.inc(n)

    def record_resume(self, n: int = 1) -> None:
        """A swapped-out sequence was swapped back in."""
        self._resumes.inc(n)

    def record_ttft(self, dt: float) -> None:
        """Wall-clock submit -> first-token time for one request."""
        self._ttft.observe(dt)

    def record_queue_wait(self, dt: float) -> None:
        """Wall-clock submit -> admission time for one request."""
        self._queue_wait.observe(dt)

    def record_step_compiles(self, n: int) -> None:
        """Backend compiles observed while an engine step ran (should
        stay 0 after warmup — the DispatchGuard invariant)."""
        self._step_compiles.inc(n)

    def record_host_sync(self, n: int = 1) -> None:
        """A sanctioned explicit device->host sync (batched
        ``jax.device_get``)."""
        self._host_syncs.inc(n)

    def record_memory(
        self,
        *,
        n_pages: int,
        live_pages: int,
        cached_pages: int,
        reserved_pages: int,
        cow_reserve_pages: int,
        host_swap_bytes: int,
        device_bytes_in_use: int = 0,
    ) -> None:
        """End-of-step device-memory snapshot (gauges, last value wins).

        Fragmentation is the share of nominally-usable headroom that is
        *not* immediately admittable: reclaimable prefix-cache pages and
        COW reserve sit between "free" and "live", so a pool can look
        half empty while admission stalls.
        """
        self._mem_pool_pages.set(n_pages)
        self._mem_live_pages.set(live_pages)
        self._mem_cached_pages.set(cached_pages)
        self._mem_reserved_pages.set(reserved_pages)
        self._mem_cow_reserve_pages.set(cow_reserve_pages)
        headroom = n_pages - live_pages
        frag = (
            (cached_pages + cow_reserve_pages) / headroom
            if headroom > 0
            else 0.0
        )
        self._mem_fragmentation.set(round(min(1.0, frag), 4))
        self._mem_host_swap_bytes.set(host_swap_bytes)
        self._mem_device_bytes_in_use.set(device_bytes_in_use)

    def record_slo_state(
        self, state_code: int, fast_burn: float, slow_burn: float
    ) -> None:
        """Latest burn-rate monitor evaluation (0=OK 1=WARN 2=CRITICAL)."""
        self._slo_state.set(state_code)
        self._slo_burn_fast.set(round(fast_burn, 6))
        self._slo_burn_slow.set(round(slow_burn, 6))

    def record_flight_incident(self, kind: str) -> None:
        """One flight-recorder bundle written (labeled by trigger kind)."""
        self._flight_incidents.inc(1, label=kind)

    def record_decode_indexed(self, n_pages: int) -> None:
        """Decode-written full pages indexed into the radix tree when
        their sequence finished."""
        self._decode_indexed.inc(n_pages)

    def record_prefix_lookup(
        self, hit_tokens: int, prompt_tokens: int, hit_pages: int
    ) -> None:
        """One admission's radix-tree walk: ``hit_tokens`` of the
        ``prompt_tokens``-token prompt came from ``hit_pages`` shared
        pages (0s for a miss)."""
        self._prefix_lookups.inc()
        self._prefix_hit_tokens.inc(hit_tokens)
        self._prefix_prompt_tokens.inc(prompt_tokens)
        self._prefix_hit_pages.inc(hit_pages)

    def record_cow(self, n: int = 1) -> None:
        """Copy-on-write page splits (a slot writing into a shared or
        radix-indexed page got a private device-side copy)."""
        self._cow_copies.inc(n)

    def record_reclaimed(self, n_pages: int) -> None:
        """Reservation pages returned to the admission budget by a
        sequence that finished before exhausting its lifetime budget."""
        self._pages_reclaimed.inc(n_pages)

    # ---- folding -----------------------------------------------------
    @staticmethod
    def _pcts(samples: list[float]) -> dict:
        arr = np.asarray(samples, np.float64)
        if not arr.size:
            return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}
        return {
            f"p{q}_ms": round(float(np.percentile(arr, q)) * 1e3, 3)
            for q in (50, 95, 99)
        }

    def summary(self) -> dict:
        lat = np.asarray(self._step_latency.samples, np.float64)
        occ = self._occupancy.samples
        total_time = self.prefill_time + self.decode_time
        # per-token latency: the wall time a decode step spent per emitted
        # token (steps emit one token per active slot)
        return {
            "requests_finished": self.finished,
            "generated_tokens": self.generated,
            # finished requests / emitted tokens per sampler kind
            "by_sampler": {
                kind: {"requests": r, "tokens": t}
                for kind, (r, t) in sorted(self.by_sampler.items())
            },
            "pages_reclaimed_early": self.pages_reclaimed_early,
            # the engine overlays enabled/inserted/evicted/cached gauges
            # when its prefix cache is on (Engine.stats_summary)
            "prefix_cache": {
                "enabled": False,
                "lookups": self.prefix_lookups,
                "hit_tokens": self.prefix_hit_tokens,
                "prompt_tokens": self.prefix_prompt_tokens,
                "hit_pages": self.prefix_hit_pages,
                "hit_rate": round(
                    self.prefix_hit_tokens / self.prefix_prompt_tokens, 4
                )
                if self.prefix_prompt_tokens
                else 0.0,
                "cow_copies": self.cow_copies,
                "decode_indexed_pages": self.decode_indexed_pages,
            },
            # the engine overlays the swap manager's byte/page counters
            # (Engine.stats_summary)
            "preemption": {
                "preemptions": self.preemptions,
                "resumes": self.resumes,
            },
            "rejected": {
                "total": sum(self.rejected.values()),
                **{k: v for k, v in sorted(self.rejected.items())},
            },
            # SLO attainment over deadline'd requests (rejected
            # deadline'd requests count as missed)
            "slo": {
                "with_deadline": self.slo_total,
                "met": self.slo_met,
                "attainment": round(self.slo_met / self.slo_total, 4)
                if self.slo_total
                else 1.0,
            },
            "ttft_ms": self._pcts(self._ttft.samples),
            "queue_wait_ms": self._pcts(self._queue_wait.samples),
            # DispatchGuard correlation: compiles seen during steps (0
            # after warmup) and sanctioned explicit host syncs
            "dispatch_guard": {
                "step_compiles": self._step_compiles.value,
                "host_syncs": self._host_syncs.value,
            },
            "prefill_calls": self.prefills,
            "prefill_requests": self.prefill_requests,
            # batched admission quality: requests admitted per jit'd
            # prefill call, overall and per compiled (N, S) bucket
            "mean_prefill_batch": round(
                self.prefill_requests / self.prefills, 4
            )
            if self.prefills
            else 0.0,
            "prefill_by_bucket": {
                f"{n}x{s}": {"calls": c, "requests": r}
                for (n, s), (c, r) in sorted(self.prefill_buckets.items())
            },
            "prefill_tokens": self.prefill_tokens,
            "prefill_s": round(self.prefill_time, 6),
            "decode_s": round(self.decode_time, 6),
            "total_s": round(total_time, 6),
            "decode_steps": self.decode_steps,
            "tok_s": round(self.generated / total_time, 2)
            if total_time > 0
            else 0.0,
            # decode throughput counts only decode-step tokens (generated
            # also includes each request's prefill-emitted first token)
            "decode_tok_s": round(
                self._decode_tokens.value / self.decode_time, 2
            )
            if self.decode_time > 0
            else 0.0,
            "prefill_tok_s": round(
                self.prefill_tokens / self.prefill_time, 2
            )
            if self.prefill_time > 0
            else 0.0,
            "p50_token_latency_ms": round(
                float(np.percentile(lat, 50)) * 1e3, 3
            )
            if lat.size
            else 0.0,
            "p95_token_latency_ms": round(
                float(np.percentile(lat, 95)) * 1e3, 3
            )
            if lat.size
            else 0.0,
            "p99_token_latency_ms": round(
                float(np.percentile(lat, 99)) * 1e3, 3
            )
            if lat.size
            else 0.0,
            "mean_occupancy": round(float(np.mean(occ)), 4)
            if occ
            else 0.0,
            "min_occupancy": round(float(np.min(occ)), 4)
            if occ
            else 0.0,
            "max_occupancy": round(float(np.max(occ)), 4)
            if occ
            else 0.0,
        }
