"""Latency / throughput / occupancy tracking for the serving engine.

Everything is recorded host-side per engine step; ``summary()`` folds the
raw samples into the numbers the benchmark emits (tok/s, p50/p95 per-token
latency, batch occupancy).
"""

from __future__ import annotations

import numpy as np

__all__ = ["ServeStats"]


class ServeStats:
    def __init__(self):
        self.prefill_tokens = 0
        self.prefill_time = 0.0
        self.prefills = 0
        self.decode_time = 0.0
        self.decode_steps = 0
        self.generated = 0
        self._step_latency: list[float] = []   # s per decode step
        self._step_active: list[int] = []      # active slots per step
        self._occupancy: list[float] = []
        self.finished = 0

    # ---- recording ---------------------------------------------------
    def record_prefill(
        self, n_tokens: int, dt: float, emitted: int = 0
    ) -> None:
        """``emitted``: tokens *generated* by this prefill (the argmax of
        the last-prompt-token logits is the request's first output)."""
        self.prefills += 1
        self.prefill_tokens += n_tokens
        self.prefill_time += dt
        self.generated += emitted

    def record_decode_step(
        self, n_active: int, max_slots: int, dt: float
    ) -> None:
        """A decode step emits one token per active slot."""
        self.decode_steps += 1
        self.decode_time += dt
        self.generated += n_active
        self._step_latency.append(dt)
        self._step_active.append(n_active)
        self._occupancy.append(n_active / max_slots)

    def record_finish(self, n: int = 1) -> None:
        self.finished += n

    # ---- folding -----------------------------------------------------
    def summary(self) -> dict:
        lat = np.asarray(self._step_latency, np.float64)
        total_time = self.prefill_time + self.decode_time
        # per-token latency: the wall time a decode step spent per emitted
        # token (steps emit one token per active slot)
        return {
            "requests_finished": self.finished,
            "generated_tokens": self.generated,
            "prefill_tokens": self.prefill_tokens,
            "prefill_s": round(self.prefill_time, 6),
            "decode_s": round(self.decode_time, 6),
            "total_s": round(total_time, 6),
            "decode_steps": self.decode_steps,
            "tok_s": round(self.generated / total_time, 2)
            if total_time > 0
            else 0.0,
            # decode throughput counts only decode-step tokens (generated
            # also includes each request's prefill-emitted first token)
            "decode_tok_s": round(
                sum(self._step_active) / self.decode_time, 2
            )
            if self.decode_time > 0
            else 0.0,
            "prefill_tok_s": round(
                self.prefill_tokens / self.prefill_time, 2
            )
            if self.prefill_time > 0
            else 0.0,
            "p50_token_latency_ms": round(
                float(np.percentile(lat, 50)) * 1e3, 3
            )
            if lat.size
            else 0.0,
            "p95_token_latency_ms": round(
                float(np.percentile(lat, 95)) * 1e3, 3
            )
            if lat.size
            else 0.0,
            "mean_occupancy": round(float(np.mean(self._occupancy)), 4)
            if self._occupancy
            else 0.0,
            "min_occupancy": round(float(np.min(self._occupancy)), 4)
            if self._occupancy
            else 0.0,
            "max_occupancy": round(float(np.max(self._occupancy)), 4)
            if self._occupancy
            else 0.0,
        }
