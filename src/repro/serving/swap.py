"""Host-memory page swap: preempt a running sequence without losing it.

``SwapManager`` moves a victim sequence's KV pages out of the device
pool into host memory so the scheduler can hand its slot and pages to a
higher-priority request, and restores them bit-exactly when the victim
resumes. Three rules keep it cheap and prefix-cache-correct:

- **Shared pages are never copied.** A page mapped into another slot
  (refcount > 1) stays device-resident no matter what — copying it out
  would buy nothing. The manager *pins* the victim's shared prefix
  (``kv.incref``) so those pages survive until resume, then releases the
  pin once the resumed slot holds its own reference.
- **Radix-indexed pages park, they don't block.** A victim's private
  pages that the prefix cache still indexes are copied to host *and*
  parked (``free_slot``'s ``keep`` hook), so they remain evictable
  headroom for the preemptor; if they are still parked (or re-adopted by
  someone else) at resume time, the engine's radix re-match maps them
  straight back in and the host copy for those pages is simply dropped —
  the copy is a fallback, not the fast path.
- **The device→host copy is asynchronous.** ``swap_out`` gathers the
  victim's private pages into a standalone device array (a jit'd gather
  — by XLA's functional semantics the preemptor reusing the freed pages
  cannot corrupt it), starts a non-blocking transfer
  (``copy_to_host_async``), and returns immediately; the engine calls
  ``finalize`` after the *next* decode step, overlapping the DMA with
  real work, which drops the device-side staging copy.

Page-count shapes are padded to powers of two (padding rows gather from
/ scatter to the trash page 0, whose contents every read masks), so the
gather/scatter programs stay O(log) like every other serving jit.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.guards import hot_path
from repro.distributed import sharding as sharding_lib
from repro.obs import MetricsRegistry
from repro.serving.kv_cache import PagedKVCache

__all__ = ["SwapManager", "SwapRecord", "SwapStats"]


@jax.jit
def _gather_pages(buffers, idx: jax.Array):
    """Pull pages ``idx`` out of every layer pool into standalone
    (layers, n, page, kv_heads, head_dim) staging arrays."""
    return jax.tree.map(lambda b: b[:, idx], buffers)


def _scatter_pages_impl(buffers, idx: jax.Array, data, *, shardings):
    """Write staged page data back into pool pages ``idx`` (duplicate
    trash-page padding entries all target page 0, whose contents are
    masked by every read)."""
    out = jax.tree.map(
        lambda b, d: b.at[:, idx].set(d), buffers, data
    )
    return sharding_lib.constrain_pools(out, shardings)


def _pad_pow2(pages: list[int]) -> np.ndarray:
    n = 1 << (len(pages) - 1).bit_length() if len(pages) > 1 else 1
    idx = np.zeros((n,), np.int32)  # padding rows hit the trash page
    idx[: len(pages)] = pages
    return idx


@dataclasses.dataclass
class SwapRecord:
    """Everything needed to restore one swapped-out sequence."""

    slot_was: int
    # the victim's shared logical-prefix pages, kept live by one pin
    # each until resume (released by ``swap_in``/``discard``)
    pin_pages: list[int]
    # host-copied logical pages [len(pin_pages), n_logical)
    n_host: int
    # staging tree: device arrays until ``finalize``, numpy after
    host: list | None
    # True while ``host`` still holds device arrays
    pending: bool = False

    @property
    def n_logical(self) -> int:
        return len(self.pin_pages) + self.n_host


class SwapStats:
    """View over the engine's metrics registry (see `repro.obs`); the
    attribute surface (``swap_outs`` etc.) is unchanged from the ad-hoc
    int era so tests and callers keep reading plain numbers."""

    def __init__(self, registry: MetricsRegistry | None = None):
        reg = registry if registry is not None else MetricsRegistry()
        self.registry = reg
        self._swap_outs = reg.counter(
            "repro_swap_outs_total", "sequences swapped out to host"
        )
        self._swap_ins = reg.counter(
            "repro_swap_ins_total", "sequences swapped back in"
        )
        self._out_pages = reg.counter(
            "repro_swap_out_pages_total", "pages copied device->host"
        )
        self._in_pages = reg.counter(
            "repro_swap_in_pages_total", "pages copied host->device"
        )
        self._out_bytes = reg.counter(
            "repro_swap_out_bytes_total", "bytes copied device->host"
        )
        self._in_bytes = reg.counter(
            "repro_swap_in_bytes_total", "bytes copied host->device"
        )
        # shared pages spared the copy
        self._pinned_pages = reg.counter(
            "repro_swap_pinned_pages_total",
            "shared pages pinned in place of a copy",
        )

    swap_outs = property(lambda self: self._swap_outs.value)
    swap_ins = property(lambda self: self._swap_ins.value)
    out_pages = property(lambda self: self._out_pages.value)
    in_pages = property(lambda self: self._in_pages.value)
    out_bytes = property(lambda self: self._out_bytes.value)
    in_bytes = property(lambda self: self._in_bytes.value)
    pinned_pages = property(lambda self: self._pinned_pages.value)

    def record_out(self, pages: int, bytes_: int, pinned: int) -> None:
        self._swap_outs.inc()
        self._out_pages.inc(pages)
        self._out_bytes.inc(bytes_)
        self._pinned_pages.inc(pinned)

    def record_in(self, pages: int, bytes_: int) -> None:
        self._in_pages.inc(pages)
        self._in_bytes.inc(bytes_)

    def record_swap_in(self) -> None:
        self._swap_ins.inc()

    def snapshot(self) -> dict:
        return {
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "out_pages": self.out_pages,
            "in_pages": self.in_pages,
            "out_bytes": self.out_bytes,
            "in_bytes": self.in_bytes,
            "pinned_pages": self.pinned_pages,
        }


class SwapManager:
    def __init__(
        self,
        kv: PagedKVCache,
        *,
        page_in_tree: Callable[[int], bool] | None = None,
        metrics: MetricsRegistry | None = None,
    ):
        """``page_in_tree``: the prefix cache's membership probe (None
        when the cache is off) — used both as ``free_slot``'s keep hook
        (private indexed pages park instead of freeing) and to classify
        which pages the radix re-match can restore without a copy.
        ``metrics``: the engine's shared registry (the stats view
        creates a private one when absent)."""
        self.kv = kv
        self.page_in_tree = page_in_tree
        self.stats = SwapStats(metrics)
        # Restore scatter, jit'd per manager so the sharded-pool layout
        # pin (constrain_pools, jaxlint JL005) closes over this pool's
        # shardings; single-device pools close over None (no-op).
        self._scatter_pages = jax.jit(
            functools.partial(_scatter_pages_impl, shardings=kv.shardings),
            donate_argnums=(0,),
        )
        # bytes one page occupies across every layer pool
        self.page_bytes = sum(
            int(np.prod(b.shape[0:1] + b.shape[2:])) * b.dtype.itemsize
            for pool in kv.buffers
            for b in pool.values()
        )

    # ---- out ---------------------------------------------------------
    def swap_out(self, slot: int, *, max_pin: int | None = None) -> SwapRecord:
        """Evacuate ``slot``: pin its shared logical-prefix pages (no
        copy), stage every other owned page for an async device→host
        copy, and free the slot. ``max_pin`` caps how many leading pages
        may be pinned instead of copied (the engine passes the radix
        match cap, ``(plen - 1) // page``, so resume's re-match is
        always able to cover the pinned prefix). Returns immediately —
        call ``finalize`` after the next decode step."""
        kv = self.kv
        owned = kv.owned_pages(slot)
        if not owned:
            raise ValueError(f"slot {slot} owns no pages (nothing to swap)")
        n_pin = 0
        cap = len(owned) if max_pin is None else min(max_pin, len(owned))
        while n_pin < cap and kv.refcount(owned[n_pin]) > 1:
            n_pin += 1
        pin_pages, host_pages = owned[:n_pin], owned[n_pin:]
        host = None
        if host_pages:
            idx = _pad_pow2(host_pages)
            host = _gather_pages(kv.buffers, jnp.asarray(idx))
            for leaf in jax.tree.leaves(host):
                leaf.copy_to_host_async()
        for p in pin_pages:
            kv.incref(p)  # survives until swap_in/discard releases it
        kv.free_slot(slot, keep=self.page_in_tree)
        self.stats.record_out(
            len(host_pages), len(host_pages) * self.page_bytes, n_pin
        )
        return SwapRecord(
            slot_was=slot,
            pin_pages=pin_pages,
            n_host=len(host_pages),
            host=host,
            pending=host is not None,
        )

    @hot_path
    def finalize(self, record: SwapRecord) -> None:
        """Materialize the staged copy on the host and drop the
        device-side staging arrays (freeing their pool-sized device
        footprint). The async transfer has been overlapping the decode
        step(s) since ``swap_out``; this is at worst a short wait."""
        if not record.pending:
            return
        # One batched fetch of the whole staging tree; the DMA has been
        # in flight since swap_out, so this lands, not blocks.
        record.host = jax.device_get(record.host)  # jaxlint: disable=JL001 -- the sanctioned explicit sync that lands an async swap-out transfer
        record.pending = False

    # ---- in ----------------------------------------------------------
    def swap_in(
        self, record: SwapRecord, slot: int, *, n_resident: int
    ) -> None:
        """Restore a swapped sequence into ``slot``. The engine has
        already mapped logical pages [0, n_resident) — the pinned prefix
        plus whatever the radix re-match recovered beyond it — and
        allocated fresh pages for [n_resident, n_logical); this scatters
        the host copies into those fresh pages and releases the record's
        pins (each pinned page is now held by the slot's own
        reference)."""
        kv = self.kv
        n_pin = len(record.pin_pages)
        if n_resident < n_pin:
            raise ValueError(
                f"resume re-match covered {n_resident} pages but "
                f"{n_pin} were pinned — pinned pages stay matchable"
            )
        if kv.pages_owned(slot) < record.n_logical:
            raise ValueError(
                f"slot {slot} owns {kv.pages_owned(slot)} pages; "
                f"restore needs {record.n_logical}"
            )
        if record.n_host:
            self.finalize(record)  # no-op if already materialized
            idx = np.zeros((_pad_pow2([0] * record.n_host).size,), np.int32)
            # host row j holds logical page n_pin + j; rows the re-match
            # already covered scatter to the trash page (dropped)
            restored = 0
            for j in range(record.n_host):
                li = n_pin + j
                if li < n_resident:
                    continue
                idx[j] = int(kv.page_table[slot, li])
                restored += 1
            kv.buffers = self._scatter_pages(
                kv.buffers,
                jnp.asarray(idx),
                jax.tree.map(jnp.asarray, record.host),
            )
            self.stats.record_in(restored, restored * self.page_bytes)
        for p in record.pin_pages:
            kv.unpin(p)
        record.host = None
        self.stats.record_swap_in()

    def discard(self, record: SwapRecord) -> None:
        """Abandon a swapped sequence (it was cancelled or timed out):
        release the pins and drop the host copy."""
        for p in record.pin_pages:
            self.kv.unpin(p)
        record.pin_pages = []
        record.host = None
        record.pending = False
