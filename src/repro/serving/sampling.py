"""Per-request batched sampling, fused into the jit'd serving steps.

``SamplingParams`` rides on each ``Request``; the engine packs the active
slots' params into ``(slots,)``-shaped device arrays and the sampler runs
*inside* the jit'd ``prefill_paged`` / ``decode_step_paged`` programs
(``sample_prefill`` / ``sample_decode`` below), so a sampled decode step
costs the same single host sync as the greedy baseline: the jit returns
the chosen token ids, never the ``(slots, V)`` logits.

Determinism: every request's noise stream is derived from its own seed,
``fold_in(key(seed), sample_idx)`` where ``sample_idx`` counts the tokens
the request has emitted (0 = the prefill-emitted first token). Neither
the slot a request lands in, the step the engine is on, nor the batch it
shares a program with enters the derivation — the same seed yields the
same tokens under any admission order, slot reuse, or bucket composition.

Filtering follows the standard serving convention (temperature, then
top-k, then top-p on the renormalized mass), with an HF-style repetition
penalty over the tokens the sequence has already seen (prompt +
generated, tracked in a device-resident ``(slots, V+1)`` presence buffer
whose last column absorbs padding scatters). ``temperature == 0`` takes
the exact argmax of the (penalty-adjusted) logits — with the default
``repetition_penalty=1.0`` this is bit-identical to the greedy oracle.

``reference_sample`` is the host-side numpy oracle for the fused path:
same key derivation and noise bits, independent filtering/argmax code.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SamplingParams",
    "GREEDY",
    "base_key_data",
    "sample_logits",
    "sample_decode",
    "sample_prefill",
    "reference_sample",
]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding knobs. Defaults are exact greedy.

    temperature: 0 -> greedy argmax; > 0 -> softmax sampling.
    top_k: keep only the k highest logits (0 -> disabled).
    top_p: keep the smallest prefix of the sorted distribution whose
        mass reaches p (1.0 -> disabled).
    repetition_penalty: HF-style penalty (> 1 discourages) applied to
        every token already in the sequence (prompt + generated).
    seed: PRNG seed for this request's noise stream; two requests with
        the same seed draw identical noise.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    repetition_penalty: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0 < self.top_p <= 1:
            raise ValueError("top_p must be in (0, 1]")
        if self.repetition_penalty <= 0:
            raise ValueError("repetition_penalty must be > 0")
        if not 0 <= self.seed < 2**63:
            raise ValueError("seed must be a non-negative 63-bit int")

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0

    @property
    def is_plain(self) -> bool:
        """True when decoding needs no sampler state at all — plain
        argmax with no noise and no presence tracking. (Greedy with a
        repetition penalty still needs the presence buffer.)"""
        return self.is_greedy and self.repetition_penalty == 1.0

    @property
    def kind(self) -> str:
        """Stats bucket: which filters are live for this request."""
        if self.is_greedy:
            # a live penalty changes greedy output (argmax of the
            # penalty-adjusted logits) — report it
            return "greedy" if self.is_plain else "greedy+rep_pen"
        parts = ["temperature"]
        if self.top_k > 0:
            parts.append("top_k")
        if self.top_p < 1:
            parts.append("top_p")
        if self.repetition_penalty != 1.0:
            parts.append("rep_pen")
        return "+".join(parts)


GREEDY = SamplingParams()


def base_key_data(seed: int) -> np.ndarray:
    """The request's base PRNG key as raw ``(2,)`` uint32 threefry data
    (the hi/lo split ``jax.random.PRNGKey`` uses). Derived from the seed
    alone, so it is identical across processes, slots and batches."""
    return np.array(
        [(seed >> 32) & 0xFFFFFFFF, seed & 0xFFFFFFFF], dtype=np.uint32
    )


# ----------------------------------------------------------------------
# Fused (in-jit) path
# ----------------------------------------------------------------------


def _penalize(logits: jax.Array, rep: jax.Array, seen: jax.Array):
    """HF repetition penalty on already-seen tokens: positive logits are
    divided by the penalty, negative multiplied. ``rep == 1`` is exact
    identity (x/1 and x*1 are bit-exact), preserving greedy parity."""
    r = rep[:, None]
    pen = jnp.where(logits > 0, logits / r, logits * r)
    return jnp.where(seen, pen, logits)


def sample_logits(
    logits: jax.Array,
    temp: jax.Array,
    top_k: jax.Array,
    top_p: jax.Array,
    rep: jax.Array,
    keys: jax.Array,
    idx: jax.Array,
    seen: jax.Array,
    valid_vocab: int | None = None,
    candidates: int | None = None,
) -> jax.Array:
    """Batched per-row sampling: logits (B, V) -> token ids (B,) int32.

    All knobs are per-row ``(B,)`` arrays (``keys`` is ``(B, 2)`` uint32
    base key data, ``idx`` the per-row sample index, ``seen`` a ``(B, V)``
    bool presence mask). Rows are fully independent — a row's token never
    depends on which other rows share the program (batch-composition
    determinism). Rows with ``temp <= 0`` take the exact argmax.

    ``candidates``: static candidate cap C — the sampled branch draws
    from the top-C logits only (``lax.top_k``, O(V log C)), instead of a
    full O(V log V) sort that is ruinous at production vocab sizes (a
    full argsort over a 50k vocab costs ~100ms/step on CPU; top-64
    ~0.5ms). top-k ranks and top-p mass are computed over the candidate
    set (renormalized); ``None`` means no cap (exact full-vocab
    semantics). The greedy branch is never capped.

    ``valid_vocab``: logits columns past it (embedding padding,
    ``cfg.padded_vocab > cfg.vocab_size``) are excluded from the
    *sampled* branch — a flattened distribution must not emit
    out-of-vocab ids. The greedy branch stays the raw argmax, bit-equal
    to the ``jnp.argmax(logits)`` oracle path.
    """
    v = logits.shape[-1]
    logits = _penalize(logits.astype(jnp.float32), rep, seen)
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits / jnp.maximum(temp, 1e-6)[:, None]
    if valid_vocab is not None and valid_vocab < v:
        scaled = jnp.where(
            jnp.arange(v)[None, :] < valid_vocab, scaled, -jnp.inf
        )
    # ONE top-C selection serves every filter; the draw happens in
    # sorted candidate space (noise indexed by rank, winner mapped back
    # through ``order``), so no threshold re-scan and no inverse sort.
    c = v if candidates is None else min(int(candidates), v)
    sx, order = jax.lax.top_k(scaled, c)  # ties: lower token id first
    rank = jnp.arange(c)[None, :]
    # top-k by rank: keep exactly k (0 or >= C disables)
    k = jnp.where((top_k <= 0) | (top_k >= c), c, top_k)
    keep = rank < k[:, None]
    sx = jnp.where(keep, sx, -jnp.inf)
    # top-p over the (renormalized) post-top-k candidate mass: keep the
    # smallest sorted prefix whose mass reaches p
    sp = jax.nn.softmax(sx, axis=-1)
    mass_before = jnp.cumsum(sp, axis=-1) - sp
    keep &= (mass_before < top_p[:, None]) | (top_p >= 1.0)[:, None]
    sx = jnp.where(keep, sx, -jnp.inf)

    # Gumbel-max draw from each row's own (seed, sample_idx) stream
    gumbel = jax.vmap(
        lambda kk, i: jax.random.gumbel(
            jax.random.fold_in(kk, i), (c,), jnp.float32
        )
    )(keys, idx)
    j = jnp.argmax(sx + gumbel, axis=-1)
    sampled_tok = jnp.take_along_axis(order, j[:, None], axis=-1)[:, 0]
    return jnp.where(
        temp <= 0.0, greedy_tok, sampled_tok.astype(jnp.int32)
    )


def _core(logits, samp, seen, valid_vocab, candidates):
    return sample_logits(
        logits,
        samp["temp"],
        samp["top_k"],
        samp["top_p"],
        samp["rep"],
        samp["key"],
        samp["idx"],
        seen,
        valid_vocab,
        candidates,
    )


def sample_decode(
    logits: jax.Array,
    samp: dict,
    *,
    valid_vocab: int | None = None,
    candidates: int | None = None,
):
    """Decode-step sampling over every slot. ``logits`` (slots, V);
    ``samp`` holds the slot-indexed param arrays plus the ``(slots,
    V+1)`` presence buffer. Idle slots sample too (their tokens are
    ignored host-side and their presence rows are reset at the next
    admission) — the program shape never depends on occupancy.
    Returns (tokens (slots,) int32, updated presence).
    """
    v = logits.shape[-1]
    presence = samp["presence"]
    toks = _core(logits, samp, presence[:, :v], valid_vocab, candidates)
    presence = presence.at[jnp.arange(toks.shape[0]), toks].set(True)
    return toks, presence


def sample_prefill(
    logits: jax.Array,
    tokens: jax.Array,
    plens: jax.Array,
    samp: dict,
    *,
    valid_vocab: int | None = None,
    candidates: int | None = None,
):
    """First-token sampling for one admission group. ``logits`` (N, V)
    last-real-token logits; ``tokens`` (N, S) the bucket-padded prompts;
    ``samp`` carries per-request ``(N,)`` params plus ``slots`` (N,) —
    the cache slot each request landed in — and the full ``(max_slots,
    V+1)`` presence buffer. Ragged prompts mask their padding by
    scattering it to the trash column V. Returns (tokens (N,) int32,
    updated presence)."""
    v = logits.shape[-1]
    s = tokens.shape[1]
    presence = samp["presence"]
    slots = samp["slots"]
    presence = presence.at[slots].set(False)
    tok_or_trash = jnp.where(
        jnp.arange(s)[None, :] < plens[:, None], tokens, v
    )
    presence = presence.at[slots[:, None], tok_or_trash].set(True)
    toks = _core(
        logits, samp, presence[slots][:, :v], valid_vocab, candidates
    )
    presence = presence.at[slots, toks].set(True)
    return toks, presence


# ----------------------------------------------------------------------
# Host-side reference oracle
# ----------------------------------------------------------------------


def reference_sample(
    logits: np.ndarray,
    params: SamplingParams,
    *,
    sample_idx: int,
    seen: np.ndarray | None = None,
    valid_vocab: int | None = None,
    candidates: int | None = None,
) -> int:
    """Numpy oracle for one row of the fused sampler.

    Same key derivation and the same Gumbel noise bits as the fused path
    (drawn through ``jax.random`` outside any jit), but independent
    numpy filtering/argmax code — differential parity catches fused-path
    masking or unsort bugs. ``seen``: optional (V,) bool presence row;
    ``candidates`` must match the fused path's static cap.
    """
    x = np.asarray(logits, np.float32).copy()
    v = x.shape[-1]
    if seen is not None:
        r = np.float32(params.repetition_penalty)
        pen = np.where(x > 0, x / r, x * r)
        x = np.where(np.asarray(seen, bool), pen, x)
    if params.is_greedy:
        return int(np.argmax(x))
    x = x / np.float32(max(params.temperature, 1e-6))
    if valid_vocab is not None and valid_vocab < v:
        x[valid_vocab:] = -np.inf
    # mirror the fused path: top-C candidates in one stable descending
    # sort, rank-based top-k, mass-prefix top-p, Gumbel draw in
    # candidate space
    c = v if candidates is None else min(int(candidates), v)
    order = np.argsort(-x, kind="stable")[:c]
    sx = x[order]
    keep = np.ones(c, bool)
    if 0 < params.top_k < c:
        keep[params.top_k:] = False
        sx = np.where(keep, sx, -np.inf)
    if params.top_p < 1.0:
        # sx[0] is the finite max, so e[0] == 1 and the sum is >= 1
        e = np.exp(sx - sx[0])
        sp = (e / e.sum()).astype(np.float32)
        mass_before = np.cumsum(sp, dtype=np.float32) - sp
        keep &= mass_before < np.float32(params.top_p)
    sx = np.where(keep, sx, -np.inf)
    key = jnp.asarray(base_key_data(params.seed))
    g = np.asarray(
        jax.random.gumbel(
            jax.random.fold_in(key, sample_idx), (c,), jnp.float32
        )
    )
    return int(order[np.argmax(sx + g)])
