"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention, MLPs.

Every GEMM goes through ``repro.core.pixelfly`` so the *same* layer code
serves both the dense baseline and the Pixelfly-sparsified model — the
paper's parameterization is a config flag, not a fork of the model zoo.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import attn_pattern as ap
from repro.core.pixelfly import LinearSpec, apply_linear, init_linear
from repro.kernels import ops

P_AXES_BATCH = ("pod", "data")


def constrain(cfg: ModelConfig, x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint guarded by launcher knobs: a no-op unless
    the launcher set tp_size/batch_axes (so model code runs unchanged on a
    single device)."""
    if not cfg.batch_axes and (not cfg.tp_size or cfg.tp_size <= 1):
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*spec))


def paged_pool_entry(cfg: ModelConfig, hk: int, d: int) -> int | None:
    """Which of a paged KV pool leaf's two trailing head axes the model
    axis shards: -2 (kv_heads) preferred, -1 (head_dim) fallback, None
    when neither divides (or no TP). The page axes always replicate —
    the host rewrites the page table every step, so any page must be
    addressable from any slot. Must agree with
    ``distributed.sharding._paged_pool_spec`` (the buffer's resting
    NamedSharding) so the in-jit constraints never force a reshard."""
    tp = cfg.tp_size or 1
    if tp <= 1:
        return None
    if hk % tp == 0 and hk >= tp:
        return -2
    if d % tp == 0 and d >= tp:
        return -1
    return None


def constrain_paged_pool(cfg: ModelConfig, buf: jax.Array) -> jax.Array:
    """Pin a slot-shared page pool's sharding inside a jit'd step: leaves
    are (..., page, kv_heads, head_dim); one head axis shards on the
    model axis per ``paged_pool_entry``, everything else replicates."""
    ax = paged_pool_entry(cfg, buf.shape[-2], buf.shape[-1])
    if ax is None:
        return buf
    spec: list = [None] * buf.ndim
    spec[buf.ndim + ax] = "model"
    return constrain(cfg, buf, *spec)


def _attn_activation_specs(cfg: ModelConfig, seq: int):
    """How to shard (b, s, hk, g, d) attention activations over the model
    axis, in preference order:
    1. kv-heads divisible by TP -> classic head sharding;
    2. q-heads divisible -> "repeat KV" (Megatron GQA practice: duplicate
       the small KV heads on every shard, shard the 64 q-heads; §Perf C4 —
       avoids the per-layer seq<->TP activation reshards of option 3);
    3. sequence-parallel (q-slice per shard against replicated KV).
    """
    tp = cfg.tp_size
    ba = cfg.batch_axes or None
    if tp <= 1:
        return None
    if cfg.num_kv_heads % tp == 0:
        return {
            "mode": "heads",
            "q": (ba, None, "model", None, None),
            "kv": (ba, None, "model", None),
            "o": (ba, None, "model", None, None),
        }
    # NOTE(§Perf C4/A4, refuted): a "repeat_kv" mode (duplicate KV heads,
    # shard the divisible q-head dim — Megatron GQA practice) measured
    # +48% collective bytes here: the repeat materialization + its
    # backward segment-reduce cost more than the seq<->TP reshards it
    # removed. Sequence-parallel stays the default for kv%tp != 0.
    if seq % tp == 0 and seq >= tp:
        return {
            "mode": "seq",
            "q": (ba, "model", None, None, None),
            "kv": (ba, None, None, None),
            "o": (ba, "model", None, None, None),
        }
    return None


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """qk-norm: RMS over the head dim of (..., heads, head_dim)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# RoPE / M-RoPE
# ----------------------------------------------------------------------


def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions (...,) -> cos, sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    mrope_sections: tuple[int, ...] = (),
) -> jax.Array:
    """x: (B, S, H, D). positions: (B, S) or (B, S, 3) for M-RoPE.

    M-RoPE (Qwen2-VL): the half-dim is split into sections, each rotated by
    its own position stream (temporal / height / width).
    """
    b, s, h, d = x.shape
    half = d // 2
    if mrope_sections:
        if positions.ndim != 3:
            raise ValueError("M-RoPE needs positions (B, S, n_sections_streams)")
        cs, ss = [], []
        off = 0
        for i, sec in enumerate(mrope_sections):
            # section frequencies are the global freq slice [off, off+sec),
            # each rotated by its own position stream (t / h / w)
            freqs = theta ** (
                -jnp.arange(off, off + sec, dtype=jnp.float32) / half
            )
            ang = positions[..., i][..., None].astype(jnp.float32) * freqs
            cs.append(jnp.cos(ang))
            ss.append(jnp.sin(ang))
            off += sec
        cos = jnp.concatenate(cs, axis=-1)
        sin = jnp.concatenate(ss, axis=-1)
    else:
        cos, sin = _rope_angles(positions, d, theta)
    cos = cos[:, :, None, :]  # (B, S, 1, half)
    sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    y1 = xf1 * cos - xf2 * sin
    y2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------
# Attention core math (portable paths; Pallas path goes via kernels.ops)
# ----------------------------------------------------------------------


def _grouped_logits(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,Hk,G,D), k (B,Sk,Hk,D) -> (B,Hk,G,Sq,Sk) fp32."""
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    )


def _grouped_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p (B,Hk,G,Sq,Sk), v (B,Sk,Hk,D) -> (B,Sq,Hk,G,D)."""
    return jnp.einsum(
        "bhgqk,bkhd->bqhgd", p, v, preferred_element_type=jnp.float32
    )


def _online_softmax_scan(q32, out_dtype, k, v, chunk, mask_fn) -> jax.Array:
    """Shared online-softmax attention core: lax.scan over KV chunks,
    never materializing the (Sq, Sk) score matrix.

    q32 (B,Sq,Hk,G,D) fp32 with ``sm_scale`` already folded in; k, v
    (B,Sk,Hk,D) with Sk a ``chunk`` multiple; ``mask_fn(ci)`` returns a
    bool mask broadcastable to the (B,Hk,G,Sq,chunk) scores of chunk
    ``ci`` (False = masked out), or None for no masking. Both the dense
    causal path and the prefix partial-prefill path run this exact body,
    so a numerics fix lands in every caller at once.
    """
    b, sq, hk, g, d = q32.shape
    n_chunks = k.shape[1] // chunk
    kc = k.reshape(b, n_chunks, chunk, hk, d)
    vc = v.reshape(b, n_chunks, chunk, hk, d)

    @jax.checkpoint
    def body(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs
        s = _grouped_logits(q32.astype(out_dtype), kb).astype(jnp.float32)
        mask = mask_fn(ci)
        if mask is not None:
            s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        masked = jnp.isneginf(m_new)
        alpha = jnp.where(masked, 1.0, jnp.exp(m - m_new))
        p = jnp.where(
            masked[..., None], 0.0, jnp.exp(s - m_new[..., None])
        )
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hk, g, sq, d), jnp.float32)
    idx = jnp.arange(n_chunks)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (idx, kc.swapaxes(0, 1), vc.swapaxes(0, 1))
    )
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None]  # (b,hk,g,sq,d)
    return out.transpose(0, 3, 1, 2, 4).astype(out_dtype)


def flash_attention_jnp(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    chunk: int,
    sm_scale: float,
) -> jax.Array:
    """Memory-efficient causal attention: lax.scan over KV chunks with
    online softmax. q (B,Sq,Hk,G,D); k, v (B,Sk,Hk,D). Never materializes
    the (Sq, Sk) score matrix. ``Sk`` need not be a chunk multiple: KV is
    zero-padded to one and the padded keys masked out.
    """
    sq = q.shape[1]
    sk_real = sk = k.shape[1]
    chunk = min(chunk, sk)
    pad = (-sk) % chunk  # KV need not be a chunk multiple: pad and mask
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q32 = q.astype(jnp.float32) * sm_scale
    qpos = jnp.arange(sq)

    def mask_fn(ci):
        if not (causal or pad):
            return None
        kpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.broadcast_to(kpos[None, :] < sk_real, (sq, chunk))
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        return mask[None, None, None]

    return _online_softmax_scan(q32, q.dtype, k, v, chunk, mask_fn)


# NOTE on scaling: q32 above holds q * sm_scale in fp32; _grouped_logits is
# fed `q32.astype(q.dtype)` so the MXU sees the model dtype. The scale is
# folded into q before the matmul (standard flash trick).


def sparse_attention_jnp(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    schedule: ap.BlockSchedule,
    *,
    causal: bool,
    sm_scale: float,
) -> jax.Array:
    """Portable pixelfly block-sparse attention, fully vectorized over q
    blocks (sparse FLOPs & bytes in HLO): every q block gathers only its
    scheduled KV blocks. No per-block loop — a loop would dynamic-slice
    the (possibly model-sharded) q-block axis and force GSPMD to
    replicate the attention compute on every shard.

    q (B,Sq,Hk,G,D); k, v (B,Sk,Hk,D).
    """
    b, sq, hk, g, d = q.shape
    sk = k.shape[1]
    bq, bk = schedule.block_q, schedule.block_k
    nqb = sq // bq
    kv_idx = jnp.asarray(schedule.kv_index)  # (nqb, w)
    valid = jnp.asarray(schedule.valid)  # (nqb, w)
    w = kv_idx.shape[1]

    qb = q.reshape(b, nqb, bq, hk, g, d)
    kb = k.reshape(b, sk // bk, bk, hk, d)
    vb = v.reshape(b, sk // bk, bk, hk, d)
    kg = jnp.take(kb, kv_idx, axis=1)  # (b, nqb, w, bk, hk, d)
    vg = jnp.take(vb, kv_idx, axis=1)

    s = (
        jnp.einsum(
            "biqhgd,biwkhd->bihgqwk", qb, kg,
            preferred_element_type=jnp.float32,
        )
        * sm_scale
    )  # (b, nqb, hk, g, bq, w, bk)
    kpos = kv_idx[:, :, None] * bk + jnp.arange(bk)[None, None, :]  # (nqb,w,bk)
    ok = (valid[:, :, None] == 1) & jnp.ones((1, 1, bk), bool)
    if causal:
        qpos = (
            jnp.arange(nqb)[:, None] * bq + jnp.arange(bq)[None, :]
        )  # (nqb, bq)
        ok = ok[:, None] & (kpos[:, None] <= qpos[..., None, None])
        # ok: (nqb, bq, w, bk)
        s = jnp.where(ok[None, :, None, None], s, -jnp.inf)
    else:
        s = jnp.where(ok[None, :, None, None, None], s, -jnp.inf)
    sf = s.reshape(*s.shape[:-2], w * bk)
    m = sf.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(sf - m)
    l = p.sum(axis=-1, keepdims=True)
    p = (p / jnp.where(l == 0.0, 1.0, l)).reshape(s.shape)
    out = jnp.einsum(
        "bihgqwk,biwkhd->biqhgd", p.astype(vg.dtype), vg,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
    return out.reshape(b, sq, hk, g, d)


def _pixelfly_visible(
    qpos: jax.Array,
    kpos: jax.Array,
    *,
    block: int,
    local_blocks: int,
    global_blocks: int,
    max_stride: int,
) -> jax.Array:
    """Elementwise causal pixelfly visibility by *absolute* positions.

    Exactly the causal-visible entries of
    ``attn_pattern.pixelfly_attention_block_mask`` on a power-of-two
    block grid: local window, global cross, and the butterfly strides
    (``qb ^ kb`` a power of two below the stride cap). Causal-visible
    entries never depend on the total block count — the stretched-grid
    construction only moves entries above the diagonal — so this rule is
    bucket-size invariant and a cached prefix sees the same mask its
    donor prefill used."""
    qb = qpos // block
    kb = kpos // block
    diff = qb ^ kb
    stride = (diff > 0) & ((diff & (diff - 1)) == 0)
    if max_stride:
        from repro.core.butterfly import next_pow2

        stride &= diff < next_pow2(max_stride)
    vis = (
        (kb < global_blocks)
        | (qb < global_blocks)
        | ((qb >= kb) & (qb - kb < local_blocks))
        | stride
    )
    return vis & (kpos <= qpos)


def prefix_flash_attention_jnp(
    q: jax.Array,
    k_suf: jax.Array,
    v_suf: jax.Array,
    k_pre: jax.Array,
    v_pre: jax.Array,
    prefix_len: jax.Array,
    *,
    sm_scale: float,
    chunk: int,
    block_cfg: tuple[int, int, int, int] | None = None,
) -> jax.Array:
    """Partial-prefill attention: suffix queries over [prefix ; suffix].

    q (B,Sq,Hk,G,D) are the *uncached suffix* queries, sitting at
    absolute positions ``prefix_len[b] + i``; k_suf/v_suf (B,Sq,Hk,D)
    their keys; k_pre/v_pre (B,Lp,Hk,D) the cached prefix K/V gathered
    through the page table (rows valid where j < prefix_len[b] — the
    rest is trash-page padding). One lax.scan over concatenated KV
    chunks with online softmax, like ``flash_attention_jnp`` but with
    per-row masks (prefix validity + causal on absolute positions).

    ``block_cfg`` = (block, local_blocks, global_blocks, max_stride)
    applies the elementwise pixelfly causal mask (``_pixelfly_visible``)
    so a sparse-attention model's partial prefill matches its full
    prefill; requires ``prefix_len`` to be block-aligned. None = dense
    causal.
    """
    sq = q.shape[1]
    lp = k_pre.shape[1]
    k = jnp.concatenate([k_pre.astype(k_suf.dtype), k_suf], axis=1)
    v = jnp.concatenate([v_pre.astype(v_suf.dtype), v_suf], axis=1)
    sk = lp + sq
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q32 = q.astype(jnp.float32) * sm_scale
    qpos = prefix_len[:, None] + jnp.arange(sq)[None, :]  # (B, Sq) abs

    def mask_fn(ci):
        j = ci * chunk + jnp.arange(chunk)  # flat [prefix ; suffix] index
        is_pre = j[None, :] < lp
        # absolute key positions: prefix token j sits at j, suffix token
        # j - lp at prefix_len + (j - lp); padded tails land beyond every
        # query position and die to the causal mask
        kpos = jnp.where(
            is_pre, j[None, :], prefix_len[:, None] + (j[None, :] - lp)
        )  # (B, chunk)
        valid = jnp.where(
            is_pre, j[None, :] < prefix_len[:, None], j[None, :] < sk
        )
        mask = (
            valid[:, None, :]
            & (kpos[:, None, :] <= qpos[:, :, None])
        )  # (B, Sq, chunk)
        if block_cfg is not None:
            blk, loc, glo, stride = block_cfg
            mask &= _pixelfly_visible(
                qpos[:, :, None],
                kpos[:, None, :],
                block=blk,
                local_blocks=loc,
                global_blocks=glo,
                max_stride=stride,
            )
        return mask[:, None, None]

    return _online_softmax_scan(q32, q.dtype, k, v, chunk, mask_fn)


def decode_attention_jnp(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    sm_scale: float,
) -> jax.Array:
    """Single-token decode: q (B,1,Hk,G,D) vs cache (B,S,Hk,D), valid <= pos."""
    s = _grouped_logits(q, k_cache) * sm_scale  # (B,Hk,G,1,S)
    sk = k_cache.shape[1]
    ok = jnp.arange(sk) <= pos
    s = jnp.where(ok[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return _grouped_out(p.astype(v_cache.dtype), v_cache).astype(q.dtype)


def sparse_decode_attention_jnp(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    sm_scale: float,
    block: int,
    local_blocks: int,
    global_blocks: int,
) -> jax.Array:
    """Beyond-paper: pixelfly-sparse *decode* — the current token's query
    attends only to its butterfly/local/global key blocks, so a 500k-token
    cache costs O(b·log n) reads instead of O(n). Block indices are computed
    from ``pos`` with the same XOR rule as the static pattern.
    """
    b_, _, hk, g, d = q.shape
    smax = k_cache.shape[1]
    nb = smax // block
    # identity page table: the contiguous cache is the paged layout with
    # logical block == physical block, so the schedule helper is shared
    table = jnp.arange(nb, dtype=jnp.int32)[None]
    idx, _, first = paged_sparse_schedule(
        table,
        jnp.asarray(pos)[None],
        block,
        local_blocks=local_blocks,
        global_blocks=global_blocks,
    )
    idx, first = idx[0], first[0]  # (w,)
    kg = jnp.take(k_cache.reshape(b_, nb, block, hk, d), idx, axis=1)
    vg = jnp.take(v_cache.reshape(b_, nb, block, hk, d), idx, axis=1)
    w = idx.shape[0]
    kg = kg.reshape(b_, w * block, hk, d)
    vg = vg.reshape(b_, w * block, hk, d)
    s = _grouped_logits(q, kg) * sm_scale
    kpos = (idx[:, None] * block + jnp.arange(block)[None, :]).reshape(-1)
    ok = kpos <= pos
    s = jnp.where(ok[None, None, None, None, :], s, -jnp.inf)
    # Duplicate blocks (XOR collisions) would double-count keys.
    ok2 = jnp.repeat(first, block)
    s = jnp.where(ok2[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return _grouped_out(p.astype(vg.dtype), vg).astype(q.dtype)


def paged_sparse_schedule(
    page_table: jax.Array,
    pos: jax.Array,
    page: int,
    *,
    local_blocks: int,
    global_blocks: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-slot pixelfly decode schedule over a paged cache.

    The cache page is the attention block, so the sparse decode schedule
    is a page-id computation: global anchors + local window + butterfly
    XOR strides of the slot's *current* block, clamped causal. Returns
    ``(logical, phys, keep)``, each (B, w): logical block ids, physical
    page ids (mapped through ``page_table``), and a first-occurrence mask
    disabling duplicate slots (XOR collisions would double-count keys).
    Shared by the jnp gather path and the Pallas kernel's scalar
    prefetch, so both read exactly the same pages.
    """
    b, np_ = page_table.shape
    cur = (pos // page).astype(jnp.int32)  # (B,) current logical block
    n_str = int(math.log2(np_)) if np_ > 1 else 0
    idx = [jnp.full((b,), i, jnp.int32) for i in range(global_blocks)]
    for j in range(local_blocks):
        idx.append(jnp.maximum(cur - j, 0))
    for t in range(n_str):
        idx.append(cur ^ (1 << t))
    idx = jnp.stack(idx, axis=1)  # (B, w) logical block ids
    idx = jnp.minimum(idx, jnp.maximum(cur, 0)[:, None])  # causal blocks only
    w = idx.shape[1]
    phys = jnp.take_along_axis(page_table, idx, axis=1)  # (B, w)
    order = jnp.argsort(idx, axis=1, stable=True)
    sorted_idx = jnp.take_along_axis(idx, order, axis=1)
    newgrp = jnp.concatenate(
        [jnp.ones((b, 1), bool), jnp.diff(sorted_idx, axis=1) != 0], axis=1
    )
    keep = jnp.zeros((b, w), bool).at[jnp.arange(b)[:, None], order].set(
        newgrp
    )
    return idx, phys, keep


def _paged_attention_kernel(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    logical: jax.Array,
    phys: jax.Array,
    keep: jax.Array,
    pos: jax.Array,
    *,
    sm_scale: float,
    impl: str,
) -> jax.Array:
    """Dispatch a (B,1,Hk,G,D) paged decode read to the Pallas kernel."""
    from repro.kernels.paged_attention import paged_decode_attention_pallas

    o = paged_decode_attention_pallas(
        q[:, 0],
        k_pages,
        v_pages,
        phys,
        logical,
        keep,
        pos,
        sm_scale=sm_scale,
        interpret=(impl == "interpret"),
    )
    return o[:, None]


def paged_decode_attention_jnp(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    pos: jax.Array,
    *,
    sm_scale: float,
    impl: str | None = None,
) -> jax.Array:
    """Decode against a block-paged KV cache (dense over logical pages).

    q (B,1,Hk,G,D); k_pages, v_pages (n_pages, page, Hk, D); page_table
    (B, P) int32 physical page per logical page; pos (B,) int32 position of
    the *current* token per slot. Unallocated table entries point at the
    shared trash page 0 — their keys land beyond ``pos`` and are masked.

    ``impl``: None/"gather" -> portable jnp gathers (the reference
    oracle); "pallas"/"interpret" -> the fused Pallas kernel reading the
    pools in place (``repro.kernels.paged_attention``).
    """
    if impl not in (None, "gather", "pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    b = q.shape[0]
    _, page, hk, d = k_pages.shape
    np_ = page_table.shape[1]
    if impl in ("pallas", "interpret"):
        logical = jnp.broadcast_to(
            jnp.arange(np_, dtype=jnp.int32)[None], (b, np_)
        )
        keep = jnp.ones((b, np_), jnp.int32)
        return _paged_attention_kernel(
            q, k_pages, v_pages, logical, page_table, keep, pos,
            sm_scale=sm_scale, impl=impl,
        )
    kg = jnp.take(k_pages, page_table, axis=0).reshape(b, np_ * page, hk, d)
    vg = jnp.take(v_pages, page_table, axis=0).reshape(b, np_ * page, hk, d)
    s = _grouped_logits(q, kg) * sm_scale  # (B,Hk,G,1,S)
    ok = jnp.arange(np_ * page)[None, :] <= pos[:, None]  # logical order
    s = jnp.where(ok[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return _grouped_out(p.astype(vg.dtype), vg).astype(q.dtype)


def paged_sparse_decode_attention_jnp(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    pos: jax.Array,
    *,
    sm_scale: float,
    local_blocks: int,
    global_blocks: int,
    impl: str | None = None,
) -> jax.Array:
    """Pixelfly-sparse paged decode: each slot's query gathers only the KV
    *pages* its butterfly/local/global schedule visits — the cache page is
    the attention block, so the sparse schedule is a page-id computation.
    O(b·log n) page reads per token instead of O(n). Shapes as in
    ``paged_decode_attention_jnp`` but with per-slot page gathers; same
    ``impl`` switch (the Pallas kernel prefetches the page-id schedule).
    """
    if impl not in (None, "gather", "pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    b = q.shape[0]
    _, page, hk, d = k_pages.shape
    idx, phys, keep = paged_sparse_schedule(
        page_table, pos, page,
        local_blocks=local_blocks, global_blocks=global_blocks,
    )
    if impl in ("pallas", "interpret"):
        return _paged_attention_kernel(
            q, k_pages, v_pages, idx, phys, keep, pos,
            sm_scale=sm_scale, impl=impl,
        )
    w = idx.shape[1]
    kg = jnp.take(k_pages, phys, axis=0).reshape(b, w * page, hk, d)
    vg = jnp.take(v_pages, phys, axis=0).reshape(b, w * page, hk, d)
    s = _grouped_logits(q, kg) * sm_scale
    kpos = (
        idx[:, :, None] * page + jnp.arange(page)[None, None, :]
    ).reshape(b, -1)
    ok = kpos <= pos[:, None]
    s = jnp.where(ok[:, None, None, None, :], s, -jnp.inf)
    ok2 = jnp.repeat(keep, page, axis=1)
    s = jnp.where(ok2[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return _grouped_out(p.astype(vg.dtype), vg).astype(q.dtype)


# ----------------------------------------------------------------------
# Attention module
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    cfg: ModelConfig

    def _lin(self, din: int, dout: int, bias: bool) -> LinearSpec:
        c = self.cfg
        if c.sparse:
            return LinearSpec.pixelfly(
                din,
                dout,
                c.sparse_density,
                block=c.sparse_block,
                lowrank_frac=c.lowrank_frac,
                use_bias=bias,
                dtype=c.jdtype,
            )
        return LinearSpec.dense(din, dout, use_bias=bias, dtype=c.jdtype)

    @property
    def wq(self) -> LinearSpec:
        return self._lin(self.cfg.d_model, self.cfg.q_dim, self.cfg.qkv_bias)

    @property
    def wk(self) -> LinearSpec:
        return self._lin(self.cfg.d_model, self.cfg.kv_dim, self.cfg.qkv_bias)

    @property
    def wv(self) -> LinearSpec:
        return self._lin(self.cfg.d_model, self.cfg.kv_dim, self.cfg.qkv_bias)

    @property
    def wo(self) -> LinearSpec:
        return self._lin(self.cfg.q_dim, self.cfg.d_model, False)


def init_attention(key: jax.Array, spec: AttnSpec) -> dict:
    c = spec.cfg
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], spec.wq),
        "wk": init_linear(ks[1], spec.wk),
        "wv": init_linear(ks[2], spec.wv),
        "wo": init_linear(ks[3], spec.wo),
    }
    if c.qk_norm:
        p["q_norm"] = jnp.ones((c.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((c.head_dim,), jnp.float32)
    return p


@functools.lru_cache(maxsize=64)
def _train_schedule(
    seq_q: int, seq_k: int, block: int, local: int, stride: int, glob: int
) -> ap.BlockSchedule:
    mask = ap.pixelfly_attention_block_mask(
        seq_q,
        seq_k,
        ap.AttentionPatternConfig(
            block=block,
            local_blocks=local,
            max_stride=stride,
            global_blocks=glob,
        ),
        causal=True,
    )
    return ap.block_schedule(mask, block, block)


def apply_attention(
    spec: AttnSpec,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "train",  # train | prefill | decode[_sparse] | decode_paged[_sparse]
    cache: dict | None = None,
    pos: jax.Array | None = None,
    page_table: jax.Array | None = None,
    impl: str | None = None,
    paged_impl: str | None = None,
):
    """Returns (y, new_cache). x: (B, S, D) [S=1 for decode].

    Paged modes: ``cache`` holds slot-shared page pools ``k``/``v`` of shape
    (n_pages, page, Hk, D), ``pos`` is per-slot (B,), and ``page_table``
    (B, P) maps each slot's logical pages to physical ones. ``paged_impl``
    selects the paged decode read: None/"gather" portable jnp gathers, or
    "pallas"/"interpret" for the fused page-pool kernel.
    """
    c = spec.cfg
    b, s, _ = x.shape
    hk, g, d = c.num_kv_heads, c.num_heads // c.num_kv_heads, c.head_dim
    scale = d ** -0.5

    q = apply_linear(spec.wq, params["wq"], x, impl=impl)
    k = apply_linear(spec.wk, params["wk"], x, impl=impl)
    v = apply_linear(spec.wv, params["wv"], x, impl=impl)
    q = q.reshape(b, s, c.num_heads, d)
    k = k.reshape(b, s, hk, d)
    v = v.reshape(b, s, hk, d)
    if c.qk_norm:
        q = head_rmsnorm(params["q_norm"], q, c.norm_eps)
        k = head_rmsnorm(params["k_norm"], k, c.norm_eps)
    q = apply_rope(q, positions, c.rope_theta, c.mrope_sections)
    k = apply_rope(k, positions, c.rope_theta, c.mrope_sections)
    qg = q.reshape(b, s, hk, g, d)
    if mode in ("train", "prefill", "prefill_prefix"):
        aspec = _attn_activation_specs(c, s)
        if aspec is not None:
            qg = constrain(c, qg, *aspec["q"])
            k = constrain(c, k, *aspec["kv"])
            v = constrain(c, v, *aspec["kv"])

    new_cache = cache
    if mode == "prefill_prefix":
        # Cache-aware partial prefill: ``cache`` holds the slot-shared
        # page pools, ``page_table`` (B, P_pre) the *cached prefix*
        # pages, ``pos`` (B,) the per-request prefix lengths (page
        # multiples; 0 for misses). Suffix queries attend the gathered
        # full-prefix keys plus their own causal window; the fresh
        # suffix K/V is returned for the caller's page scatter, the
        # shared prefix pages are read-only.
        assert cache is not None and pos is not None and page_table is not None
        page = cache["k"].shape[1]
        npre = page_table.shape[1]
        kp = jnp.take(cache["k"], page_table, axis=0).reshape(
            b, npre * page, hk, d
        )
        vp = jnp.take(cache["v"], page_table, axis=0).reshape(
            b, npre * page, hk, d
        )
        block_cfg = (
            (
                c.attn_block,
                c.attn_local_blocks,
                c.attn_global_blocks,
                c.attn_max_stride,
            )
            if c.sparse_attention
            and s >= c.attn_block
            and s % c.attn_block == 0
            else None
        )
        o = prefix_flash_attention_jnp(
            qg, k, v, kp, vp, pos,
            sm_scale=scale, chunk=c.attn_chunk, block_cfg=block_cfg,
        )
        new_cache = {"k": k, "v": v}
        aspec = _attn_activation_specs(c, s)
        if aspec is not None:
            o = constrain(c, o, *aspec["o"])
    elif mode in ("decode_paged", "decode_paged_sparse"):
        assert cache is not None and pos is not None and page_table is not None
        page = cache["k"].shape[1]
        # tensor-parallel decode: head-partition the fresh K/V and the
        # grouped queries on the same axis the pool shards, so the write
        # scatter and the attention read stay shard-local (the only
        # collective left is wo's psum).
        pool_ax = paged_pool_entry(c, hk, d)
        ba = c.batch_axes or None
        if pool_ax == -2:
            qg = constrain(c, qg, ba, None, "model", None, None)
            k = constrain(c, k, ba, None, "model", None)
            v = constrain(c, v, ba, None, "model", None)
        elif pool_ax == -1:
            qg = constrain(c, qg, ba, None, None, None, "model")
            k = constrain(c, k, ba, None, None, "model")
            v = constrain(c, v, ba, None, None, "model")
        # write-at-position: each slot's token lands in its own page; idle
        # slots all route to the shared trash page 0 (never read back).
        phys = jnp.take_along_axis(page_table, (pos // page)[:, None], axis=1)
        phys = phys[:, 0]
        off = pos % page
        kc = cache["k"].at[phys, off].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[phys, off].set(v[:, 0].astype(cache["v"].dtype))
        kc = constrain_paged_pool(c, kc)
        vc = constrain_paged_pool(c, vc)
        new_cache = {"k": kc, "v": vc}
        if mode == "decode_paged_sparse" and page == c.attn_block:
            o = paged_sparse_decode_attention_jnp(
                qg,
                kc,
                vc,
                page_table,
                pos,
                sm_scale=scale,
                local_blocks=c.attn_local_blocks,
                global_blocks=c.attn_global_blocks,
                impl=paged_impl,
            )
        else:
            o = paged_decode_attention_jnp(
                qg, kc, vc, page_table, pos, sm_scale=scale, impl=paged_impl
            )
    elif mode in ("decode", "decode_sparse"):
        assert cache is not None and pos is not None
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0)
        )
        new_cache = {"k": kc, "v": vc}
        smax = kc.shape[1]
        if mode == "decode_sparse" and (
            smax % c.attn_block or smax < 2 * c.attn_block
        ):
            mode = "decode"  # cache too small/ragged for block gathers
        if mode == "decode_sparse":
            o = sparse_decode_attention_jnp(
                qg,
                kc,
                vc,
                pos,
                sm_scale=scale,
                block=c.attn_block,
                local_blocks=c.attn_local_blocks,
                global_blocks=c.attn_global_blocks,
            )
        else:
            o = decode_attention_jnp(qg, kc, vc, pos, sm_scale=scale)
    else:
        use_sparse = (
            c.sparse_attention and s >= c.attn_block and s % c.attn_block == 0
        )
        if use_sparse:
            sched = _train_schedule(
                s,
                s,
                c.attn_block,
                c.attn_local_blocks,
                c.attn_max_stride,
                c.attn_global_blocks,
            )
            if impl in ("pallas", "interpret"):
                qf = q.transpose(0, 2, 1, 3)
                kf = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)
                vf = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
                o = ops.block_sparse_attention(
                    qf, kf, vf, sched, causal=True, sm_scale=scale, impl=impl
                )
                o = o.transpose(0, 2, 1, 3).reshape(b, s, hk, g, d)
            else:
                o = sparse_attention_jnp(
                    qg, k, v, sched, causal=True, sm_scale=scale
                )
        else:
            o = flash_attention_jnp(
                qg, k, v, causal=True, chunk=c.attn_chunk, sm_scale=scale
            )
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
        aspec = _attn_activation_specs(c, s)
        if aspec is not None:
            o = constrain(c, o, *aspec["o"])
    o = o.reshape(b, s, c.q_dim)
    y = apply_linear(spec.wo, params["wo"], o, impl=impl)
    ba = c.batch_axes or None
    y = constrain(c, y, ba, *([None] * (y.ndim - 1)))
    return y, new_cache


# ----------------------------------------------------------------------
# MLP (SwiGLU)
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpSpec:
    cfg: ModelConfig
    d_ff: int

    def _lin(self, din: int, dout: int) -> LinearSpec:
        c = self.cfg
        if c.sparse:
            return LinearSpec.pixelfly(
                din,
                dout,
                c.sparse_density,
                block=c.sparse_block,
                lowrank_frac=c.lowrank_frac,
                dtype=c.jdtype,
            )
        return LinearSpec.dense(din, dout, dtype=c.jdtype)

    @property
    def wg(self) -> LinearSpec:
        return self._lin(self.cfg.d_model, self.d_ff)

    @property
    def wu(self) -> LinearSpec:
        return self._lin(self.cfg.d_model, self.d_ff)

    @property
    def wd(self) -> LinearSpec:
        return self._lin(self.d_ff, self.cfg.d_model)


def init_mlp(key: jax.Array, spec: MlpSpec) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wg": init_linear(ks[0], spec.wg),
        "wu": init_linear(ks[1], spec.wu),
        "wd": init_linear(ks[2], spec.wd),
    }


def apply_mlp(
    spec: MlpSpec, params: dict, x: jax.Array, *, impl: str | None = None
) -> jax.Array:
    c = spec.cfg
    ba = c.batch_axes or None
    gate = apply_linear(spec.wg, params["wg"], x, impl=impl)
    up = apply_linear(spec.wu, params["wu"], x, impl=impl)
    if c.tp_size and c.tp_size > 1 and spec.d_ff % c.tp_size == 0:
        hid = (ba, *([None] * (x.ndim - 2)), "model")
        gate = constrain(c, gate, *hid)
        up = constrain(c, up, *hid)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    y = apply_linear(spec.wd, params["wd"], h, impl=impl)
    return constrain(c, y, ba, *([None] * (y.ndim - 1)))


# ----------------------------------------------------------------------
# Embeddings / head
# ----------------------------------------------------------------------


def init_embedding(key: jax.Array, cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab
    p = {
        "tok": (
            jax.random.normal(key, (v, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.jdtype)
    }
    return p


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def init_lm_head(key: jax.Array, cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    v = cfg.padded_vocab
    std = 1.0 / math.sqrt(cfg.d_model)
    return {
        "w": (
            jax.random.normal(key, (cfg.d_model, v), jnp.float32) * std
        ).astype(cfg.jdtype)
    }


def lm_logits(
    cfg: ModelConfig, head: dict, embed: dict, x: jax.Array
) -> jax.Array:
    if cfg.tie_embeddings:
        w = embed["tok"].T
    else:
        w = head["w"]
    return jnp.einsum(
        "...d,dv->...v", x, w, preferred_element_type=jnp.float32
    )
