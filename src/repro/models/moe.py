"""Mixture-of-Experts FFN: shared experts + routed top-k (DeepSeekMoE-style).

Dispatch is sort-based (token permutation into per-expert capacity buffers),
not one-hot-einsum, so the compiled FLOPs are the *activated* FLOPs — this
matters for honest roofline accounting and is also the right TPU strategy
(dense per-expert GEMMs on contiguous buffers feed the MXU).

Routing is performed independently per "routing group" (set by the launcher
to the number of data shards) so the sort/scatter never crosses the data
axis — the only cross-device traffic is the expert-parallel all-to-all that
GSPMD inserts around the (groups, experts, capacity, d) buffer.

Expert FFN weights are stored stacked as (E, ...) and carry the Pixelfly
parameterization when ``cfg.sparse`` is set (paper's technique applied to
expert GEMMs; the tiny router stays dense).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import budget as budget_lib
from repro.core import butterfly
from repro.models.layers import MlpSpec, apply_mlp, constrain, init_mlp

__all__ = ["MoeSpec", "init_moe", "apply_moe"]


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    cfg: ModelConfig

    @property
    def n_exp(self) -> int:
        return self.cfg.moe_num_experts

    @property
    def d_ff(self) -> int:
        return self.cfg.moe_d_ff

    def sparse_layout(self, din: int, dout: int):
        """(cols, r, rank) of the pixelfly pattern for an expert GEMM."""
        c = self.cfg
        rank, max_stride = budget_lib.split_sparse_lowrank(
            dout, din, c.sparse_density, block=c.sparse_block,
            lowrank_frac=c.lowrank_frac,
        )
        pat = butterfly.make_pattern(
            dout, din, block=c.sparse_block, max_stride=max_stride
        )
        return pat, rank


def _init_expert_dense(key, e, din, dout, dtype):
    std = 1.0 / math.sqrt(din)
    return (
        jax.random.normal(key, (e, din, dout), jnp.float32) * std
    ).astype(dtype)


def _init_expert_sparse(key, e, spec: MoeSpec, din, dout):
    c = spec.cfg
    pat, rank = spec.sparse_layout(din, dout)
    kb, ku, kv = jax.random.split(key, 3)
    b = c.sparse_block
    return {
        "blocks": (
            jax.random.normal(
                kb, (e, pat.nb_out, pat.r, b, b), jnp.float32
            )
            / math.sqrt(pat.r * b)
        ).astype(c.jdtype),
        "U": (
            jax.random.normal(ku, (e, din, rank), jnp.float32)
            / math.sqrt(din)
        ).astype(c.jdtype),
        "V": (
            jax.random.normal(kv, (e, dout, rank), jnp.float32)
            / math.sqrt(rank)
        ).astype(c.jdtype),
        "gamma": jnp.full((e,), 0.5, jnp.float32),
    }


def init_moe(key: jax.Array, spec: MoeSpec) -> dict:
    c = spec.cfg
    ks = jax.random.split(key, 6)
    e, d, f = spec.n_exp, c.d_model, spec.d_ff
    p: dict = {
        "router": (
            jax.random.normal(ks[0], (d, e), jnp.float32) * d ** -0.5
        ).astype(jnp.float32)
    }
    if c.sparse:
        p["wg"] = _init_expert_sparse(ks[1], e, spec, d, f)
        p["wu"] = _init_expert_sparse(ks[2], e, spec, d, f)
        p["wd"] = _init_expert_sparse(ks[3], e, spec, f, d)
    else:
        p["wg"] = _init_expert_dense(ks[1], e, d, f, c.jdtype)
        p["wu"] = _init_expert_dense(ks[2], e, d, f, c.jdtype)
        p["wd"] = _init_expert_dense(ks[3], e, f, d, c.jdtype)
    if c.moe_num_shared:
        shared = MlpSpec(c, c.moe_num_shared * spec.d_ff)
        p["shared"] = init_mlp(ks[4], shared)
    return p


def _expert_matmul(spec: MoeSpec, w, x: jax.Array, din: int, dout: int):
    """x (G, E, C, din) @ per-expert weight -> (G, E, C, dout)."""
    c = spec.cfg
    if not c.sparse:
        return jnp.einsum("gecd,edf->gecf", x, w).astype(x.dtype)
    pat, _ = spec.sparse_layout(din, dout)
    b = c.sparse_block
    cols = jnp.asarray(pat.cols)  # (nb_out, r)

    @jax.checkpoint
    def _bsr(xx, blocks):
        xb = xx.reshape(*xx.shape[:-1], din // b, b)
        y = None
        for t in range(pat.r):
            xg = jnp.take(xb, cols[:, t], axis=-2)  # (G,E,C,nb_out,b)
            yt = jnp.einsum("gecik,eikm->gecim", xg, blocks[:, :, t])
            y = yt if y is None else y + yt
        return y.reshape(*xx.shape[:-1], pat.nb_out * b)

    ys = _bsr(x, w["blocks"])
    xu = jnp.einsum("gecd,edr->gecr", x, w["U"])
    yl = jnp.einsum("gecr,eor->geco", xu, w["V"]).astype(jnp.float32)
    g = w["gamma"][None, :, None, None].astype(jnp.float32)
    return (g * ys + (1.0 - g) * yl).astype(x.dtype)


def _expert_ffn(spec: MoeSpec, params: dict, x: jax.Array) -> jax.Array:
    c = spec.cfg
    d, f = c.d_model, spec.d_ff
    gate = _expert_matmul(spec, params["wg"], x, d, f)
    up = _expert_matmul(spec, params["wu"], x, d, f)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return _expert_matmul(spec, params["wd"], h, f, d)


def apply_moe(
    spec: MoeSpec,
    params: dict,
    x: jax.Array,
    *,
    impl: str | None = None,
) -> tuple[jax.Array, dict]:
    """x (B, S, D) -> (y, aux) with aux = {"lb_loss": load-balance loss}."""
    c = spec.cfg
    b, s, d = x.shape
    e, k = spec.n_exp, c.moe_top_k
    tokens = b * s
    groups = max(1, min(c.moe_routing_groups, tokens))
    while tokens % groups:
        groups -= 1
    t = tokens // groups
    xf = x.reshape(groups, t, d)

    logits = jnp.einsum(
        "gtd,de->gte", xf.astype(jnp.float32), params["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (g, t, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = probs.mean(axis=1)  # (g, e)
    ce = (
        jnp.zeros((groups, e))
        .at[jnp.arange(groups)[:, None, None], idx]
        .add(1.0)
        / (t * k)
    )
    lb_loss = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    cap = int(c.moe_capacity_factor * t * k / e)
    cap = max(8, int(math.ceil(cap / 8) * 8))

    fe = idx.reshape(groups, t * k)  # flat expert ids
    order = jnp.argsort(fe, axis=-1, stable=True)  # (g, tk)
    se = jnp.take_along_axis(fe, order, axis=-1)  # sorted expert ids
    tok = order // k  # originating token
    # position within expert segment
    starts = jax.vmap(lambda a: jnp.searchsorted(a, jnp.arange(e)))(se)  # (g, e)
    pos = jnp.arange(t * k)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < cap
    posc = jnp.where(keep, pos, 0)

    gi = jnp.arange(groups)[:, None]
    xs = jnp.take_along_axis(
        xf, tok[..., None], axis=1
    )  # (g, tk, d) tokens sorted by expert
    xs = jnp.where(keep[..., None], xs, 0)
    buf = jnp.zeros((groups, e, cap, d), x.dtype)
    buf = buf.at[gi, se, posc].add(xs)

    # NOTE(§Perf A1, refuted): forcing (data, model) sharding on buf/yb
    # here made collectives 3.7x WORSE — GSPMD reshards the scatter/gather
    # around the anchor instead of routing through it. Kept off; the
    # winning change was A2 (see EXPERIMENTS.md).

    yb = _expert_ffn(spec, params, buf)  # (g, e, cap, d)

    ys = yb[gi, se, posc]  # (g, tk, d)
    ys = jnp.where(keep[..., None], ys, 0)
    gflat = jnp.take_along_axis(gates.reshape(groups, t * k), order, axis=-1)
    y = jnp.zeros((groups, t, d), jnp.float32)
    y = y.at[gi, tok].add(ys.astype(jnp.float32) * gflat[..., None])
    y = y.astype(x.dtype).reshape(b, s, d)

    if c.moe_num_shared:
        shared = MlpSpec(c, c.moe_num_shared * spec.d_ff)
        y = y + apply_mlp(shared, params["shared"], x, impl=impl)
    return y, {"lb_loss": lb_loss}
