"""ViT and MLP-Mixer — the paper's own §5.1 base models.

These carry the same Pixelfly parameterization through
``repro.core.pixelfly`` (linear layers) and the block-sparse attention path,
and are used by the vision benchmarks (Fig. 5 / Table 4 reproduction) and
the NTK-distance experiment (Fig. 4). They run at CPU scale here.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.pixelfly import LinearSpec, apply_linear, init_linear
from repro.models.layers import init_rmsnorm, rmsnorm

__all__ = [
    "VisionConfig",
    "init_vit",
    "apply_vit",
    "init_mixer",
    "apply_mixer",
]


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    kind: str  # "vit" | "mixer"
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    num_patches: int
    num_classes: int
    patch_dim: int  # flattened patch pixels (stubbed patchifier input)
    token_ff: int = 0  # mixer token-mixing hidden dim
    sparse: bool = False
    sparse_density: float = 0.25
    sparse_block: int = 32
    lowrank_frac: float = 0.25
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def lin(self, din: int, dout: int) -> LinearSpec:
        if self.sparse and din % self.sparse_block == 0 and dout % self.sparse_block == 0:
            return LinearSpec.pixelfly(
                din,
                dout,
                self.sparse_density,
                block=self.sparse_block,
                lowrank_frac=self.lowrank_frac,
                dtype=self.jdtype,
            )
        return LinearSpec.dense(din, dout, dtype=self.jdtype)


def _init_mlp(key, cfg: VisionConfig, din: int, dff: int, dout: int):
    k1, k2 = jax.random.split(key)
    return {
        "w1": init_linear(k1, cfg.lin(din, dff)),
        "w2": init_linear(k2, cfg.lin(dff, dout)),
    }


def _apply_mlp(cfg: VisionConfig, p, x, din, dff, dout):
    h = apply_linear(cfg.lin(din, dff), p["w1"], x)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return apply_linear(cfg.lin(dff, dout), p["w2"], h)


# ----------------------------------------------------------------------
# ViT
# ----------------------------------------------------------------------


def init_vit(key: jax.Array, cfg: VisionConfig) -> dict:
    ks = jax.random.split(key, cfg.num_layers + 3)
    d = cfg.d_model
    params = {
        "patch": init_linear(ks[0], LinearSpec.dense(cfg.patch_dim, d, dtype=cfg.jdtype)),
        "pos": (jax.random.normal(ks[1], (cfg.num_patches + 1, d)) * 0.02).astype(cfg.jdtype),
        "cls": jnp.zeros((d,), cfg.jdtype),
        # zero-init classifier head (ViT practice): logits start at 0, so
        # early full-batch steps at large lr can't overshoot through the
        # randomly-initialized backbone
        "head": {"w": jnp.zeros((d, cfg.num_classes), cfg.jdtype)},
        "final_norm": init_rmsnorm(d),
        "layers": [],
    }
    layers = []
    for i in range(cfg.num_layers):
        k1, k2, k3 = jax.random.split(ks[3 + i], 3)
        layers.append(
            {
                "n1": init_rmsnorm(d),
                "qkv": init_linear(k1, cfg.lin(d, 3 * d)),
                "proj": init_linear(k2, cfg.lin(d, d)),
                "n2": init_rmsnorm(d),
                "mlp": _init_mlp(k3, cfg, d, cfg.d_ff, d),
            }
        )
    params["layers"] = layers
    return params


def apply_vit(cfg: VisionConfig, params: dict, patches: jax.Array) -> jax.Array:
    """patches: (B, num_patches, patch_dim) -> logits (B, num_classes)."""
    b = patches.shape[0]
    d, h = cfg.d_model, cfg.num_heads
    x = apply_linear(
        LinearSpec.dense(cfg.patch_dim, d, dtype=cfg.jdtype),
        params["patch"],
        patches.astype(cfg.jdtype),
    )
    cls = jnp.broadcast_to(params["cls"][None, None], (b, 1, d))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"][None]
    hd = d // h
    for p in params["layers"]:
        y = rmsnorm(p["n1"], x)
        qkv = apply_linear(cfg.lin(d, 3 * d), p["qkv"], y)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        s = x.shape[1]
        q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) * hd ** -0.5
        att = jax.nn.softmax(att.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + apply_linear(cfg.lin(d, d), p["proj"], o)
        y = rmsnorm(p["n2"], x)
        x = x + _apply_mlp(cfg, p["mlp"], y, d, cfg.d_ff, d)
    x = rmsnorm(params["final_norm"], x)
    return apply_linear(
        LinearSpec.dense(d, cfg.num_classes, dtype=cfg.jdtype),
        params["head"],
        x[:, 0],
    ).astype(jnp.float32)


# ----------------------------------------------------------------------
# MLP-Mixer
# ----------------------------------------------------------------------


def init_mixer(key: jax.Array, cfg: VisionConfig) -> dict:
    ks = jax.random.split(key, cfg.num_layers + 2)
    d, s = cfg.d_model, cfg.num_patches
    tf = cfg.token_ff or cfg.d_ff // 2
    params = {
        "patch": init_linear(ks[0], LinearSpec.dense(cfg.patch_dim, d, dtype=cfg.jdtype)),
        "head": init_linear(ks[1], LinearSpec.dense(d, cfg.num_classes, dtype=cfg.jdtype)),
        "final_norm": init_rmsnorm(d),
        "layers": [],
    }
    layers = []
    for i in range(cfg.num_layers):
        k1, k2 = jax.random.split(ks[2 + i])
        layers.append(
            {
                "n1": init_rmsnorm(d),
                "token_mlp": _init_mlp(k1, cfg, s, tf, s),
                "n2": init_rmsnorm(d),
                "chan_mlp": _init_mlp(k2, cfg, d, cfg.d_ff, d),
            }
        )
    params["layers"] = layers
    return params


def apply_mixer(cfg: VisionConfig, params: dict, patches: jax.Array) -> jax.Array:
    """patches: (B, num_patches, patch_dim) -> logits (B, num_classes)."""
    d, s = cfg.d_model, cfg.num_patches
    tf = cfg.token_ff or cfg.d_ff // 2
    x = apply_linear(
        LinearSpec.dense(cfg.patch_dim, d, dtype=cfg.jdtype),
        params["patch"],
        patches.astype(cfg.jdtype),
    )
    for p in params["layers"]:
        y = rmsnorm(p["n1"], x).swapaxes(1, 2)  # (B, D, S)
        y = _apply_mlp(cfg, p["token_mlp"], y, s, tf, s)
        x = x + y.swapaxes(1, 2)
        y = rmsnorm(p["n2"], x)
        x = x + _apply_mlp(cfg, p["chan_mlp"], y, d, cfg.d_ff, d)
    x = rmsnorm(params["final_norm"], x)
    return apply_linear(
        LinearSpec.dense(d, cfg.num_classes, dtype=cfg.jdtype),
        params["head"],
        x.mean(axis=1),
    ).astype(jnp.float32)
