"""Unified decoder LM covering all assigned families.

A model is a sequence of *layer groups* (``cfg.layer_groups()``); each group
is a run of structurally identical blocks scanned with ``jax.lax.scan`` (+
``jax.checkpoint`` for training), so HLO size and compile time are O(#groups)
— not O(depth) — even for the 95-layer / 61-layer configs. Shared groups
(zamba2's shared attention block) reuse one parameter subtree at several
positions but keep per-position caches.

Entry points:
  forward_train(cfg, params, batch)            -> (loss, metrics)
  prefill(cfg, params, batch, max_len)         -> (logits, cache)
  decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)

Serving (block-paged KV cache, ``repro.serving``):
  init_paged_cache(cfg, n_pages, page)         -> paged cache pools
  prefill_paged(cfg, params, tokens, plens, caches, page_rows)
                                               -> ((N, V) last-real-token logits, caches)
                                               [batched: N requests, one bucket]
  decode_step_paged(cfg, params, caches, tokens, positions, page_table)
                                               -> (logits, caches)  [ragged positions]
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.analysis.guards import hot_path
from repro.configs.base import GroupSpec, ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

__all__ = [
    "init_model",
    "init_cache",
    "init_paged_cache",
    "forward_train",
    "prefill",
    "prefill_paged",
    "decode_step",
    "decode_step_paged",
    "param_count",
]


# ----------------------------------------------------------------------
# Block init / apply (one layer)
# ----------------------------------------------------------------------


def _block_specs(cfg: ModelConfig, kind: str):
    if kind in ("dense", "shared_attn"):
        d_ff = cfg.d_ff
        if kind == "dense" and cfg.family == "moe" and cfg.moe_dense_ff:
            d_ff = cfg.moe_dense_ff
        return {"attn": L.AttnSpec(cfg), "mlp": L.MlpSpec(cfg, d_ff)}
    if kind == "moe":
        return {"attn": L.AttnSpec(cfg), "moe": moe_lib.MoeSpec(cfg)}
    if kind == "ssm":
        return {"ssm": ssm_lib.SsmSpec(cfg)}
    raise ValueError(kind)


def _init_block(key: jax.Array, cfg: ModelConfig, kind: str) -> dict:
    specs = _block_specs(cfg, kind)
    ks = jax.random.split(key, 4)
    if kind in ("dense", "shared_attn"):
        return {
            "attn_norm": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], specs["attn"]),
            "mlp_norm": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks[1], specs["mlp"]),
        }
    if kind == "moe":
        return {
            "attn_norm": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], specs["attn"]),
            "mlp_norm": L.init_rmsnorm(cfg.d_model),
            "moe": moe_lib.init_moe(ks[1], specs["moe"]),
        }
    if kind == "ssm":
        return {
            "norm": L.init_rmsnorm(cfg.d_model),
            "ssm": ssm_lib.init_ssm(ks[0], specs["ssm"]),
        }
    raise ValueError(kind)


def _is_placeholder(c) -> bool:
    return c is None or (isinstance(c, jax.Array) and c.size == 0)


def _apply_block(
    cfg: ModelConfig,
    kind: str,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    cache: dict | None,
    pos: jax.Array | None,
    impl: str | None,
    page_table: jax.Array | None = None,
    paged_impl: str | None = None,
):
    """Returns (x, new_cache, lb_loss). ``cache`` may be a zero-size
    placeholder array (cache-less scan); it is normalized to None here and a
    placeholder is returned when the block produces no cache."""
    if _is_placeholder(cache):
        cache = None
    specs = _block_specs(cfg, kind)
    lb = jnp.zeros((), jnp.float32)
    # Pin the residual stream's batch sharding at every block boundary so
    # GSPMD never drifts into replicating tokens inside the layer scan.
    ba = cfg.batch_axes or None
    x = L.constrain(cfg, x, ba, *([None] * (x.ndim - 1)))
    if kind == "ssm":
        h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
        if mode in ("decode", "decode_sparse"):
            y, cache = ssm_lib.apply_ssm_decode(
                specs["ssm"], params["ssm"], h, cache, impl=impl
            )
        else:
            y, cache = ssm_lib.apply_ssm_train(
                specs["ssm"],
                params["ssm"],
                h,
                impl=impl,
                return_state=(mode == "prefill"),
            )
        if cache is None:
            cache = jnp.zeros((0,))
        return x + y, cache, lb

    h = L.rmsnorm(params["attn_norm"], x, cfg.norm_eps)
    attn_mode = mode
    if mode == "decode" and cfg.sparse_attention:
        attn_mode = "decode_sparse"
    if mode == "decode_paged" and cfg.sparse_attention:
        attn_mode = "decode_paged_sparse"
    y, cache = L.apply_attention(
        specs["attn"],
        params["attn"],
        h,
        positions,
        mode=attn_mode,
        cache=cache,
        pos=pos,
        page_table=page_table,
        impl=impl,
        paged_impl=paged_impl,
    )
    x = x + y
    h = L.rmsnorm(params["mlp_norm"], x, cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_lib.apply_moe(specs["moe"], params["moe"], h, impl=impl)
        lb = aux["lb_loss"]
    else:
        y = L.apply_mlp(specs["mlp"], params["mlp"], h, impl=impl)
    if cache is None:
        cache = jnp.zeros((0,))
    return x + y, cache, lb


# ----------------------------------------------------------------------
# Model init
# ----------------------------------------------------------------------


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    groups = cfg.layer_groups()
    n_keys = len(groups) + 3
    ks = jax.random.split(key, n_keys)
    params: dict = {
        "embed": L.init_embedding(ks[0], cfg),
        "head": L.init_lm_head(ks[1], cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "groups": {},
    }
    done: set[str] = set()
    for i, g in enumerate(groups):
        if g.param_key in done:
            continue
        done.add(g.param_key)
        kg = ks[3 + i]
        if g.shared or g.count == 1:
            p = _init_block(kg, cfg, g.kind)
            if not g.shared:
                p = jax.tree.map(lambda a: a[None], p)  # still scanned
            params["groups"][g.param_key] = p
        else:
            layer_keys = jax.random.split(kg, g.count)
            params["groups"][g.param_key] = jax.vmap(
                lambda k: _init_block(k, cfg, g.kind)
            )(layer_keys)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ----------------------------------------------------------------------
# Caches
# ----------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """One cache entry per layer group, stacked over the group's layers."""
    caches = []
    for g in cfg.layer_groups():
        if g.kind == "ssm":
            spec = ssm_lib.SsmSpec(cfg)
            one = ssm_lib.init_ssm_cache(spec, batch, cfg.jdtype)
        else:
            one = {
                "k": jnp.zeros(
                    (batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                    cfg.jdtype,
                ),
                "v": jnp.zeros(
                    (batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                    cfg.jdtype,
                ),
            }
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(
            a[None], (g.count,) + a.shape), one))
    return caches


def init_paged_cache(
    cfg: ModelConfig, n_pages: int, page: int, *, shardings: list | None = None
) -> list:
    """Slot-shared page pools, one per layer group (stacked over layers).

    Physical page 0 is reserved as the trash page (idle slots and
    unallocated table entries point at it); the serving allocator hands
    out pages 1..n_pages-1. ``shardings`` (a matching tree of
    ``NamedSharding``, built from ``sharding.cache_specs(layout="paged")``)
    places each pool across the mesh at creation — head axes sharded on
    the model axis, page axes replicated — so tensor-parallel decode
    never starts from a single-device pool.
    """
    caches = []
    for g in cfg.layer_groups():
        if g.kind == "ssm":
            raise NotImplementedError(
                "paged serving caches cover attention families; SSM state "
                "is slot-indexed, not paged"
            )
        caches.append(
            {
                "k": jnp.zeros(
                    (g.count, n_pages, page, cfg.num_kv_heads, cfg.head_dim),
                    cfg.jdtype,
                ),
                "v": jnp.zeros(
                    (g.count, n_pages, page, cfg.num_kv_heads, cfg.head_dim),
                    cfg.jdtype,
                ),
            }
        )
    if shardings is not None:
        caches = jax.tree.map(jax.device_put, caches, shardings)
    return caches


# ----------------------------------------------------------------------
# Group execution (scan over layers)
# ----------------------------------------------------------------------


def _run_group(
    cfg: ModelConfig,
    g: GroupSpec,
    gparams: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    cache,
    pos,
    impl,
    page_table=None,
    paged_impl=None,
):
    """Scan ``g.count`` blocks. Returns (x, new_cache, lb_sum)."""

    def body(carry, xs):
        xc, lb_sum = carry
        p, c_in = xs
        xc, c_out, lb = _apply_block(
            cfg, g.kind, p, xc, positions,
            mode=mode, cache=c_in, pos=pos, impl=impl,
            page_table=page_table, paged_impl=paged_impl,
        )
        return (xc, lb_sum + lb), c_out

    if g.shared:
        # one param set reused; caches still stacked per occurrence
        stacked_p = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (g.count,) + a.shape), gparams
        )
    else:
        stacked_p = gparams

    body_fn = body
    if cfg.remat and mode == "train":
        body_fn = jax.checkpoint(body)

    if cache is None:
        cache = jnp.zeros((g.count, 0))  # per-layer placeholder
    init = (x, jnp.zeros((), jnp.float32))
    xs = (stacked_p, cache)

    n1, n2 = _remat_factors(g.count) if (cfg.remat and mode == "train") else (0, 0)
    if n1 > 1 and n2 > 1:
        # Two-level (sqrt-n) remat: the backward pass keeps the residual
        # stream at n1 outer checkpoints instead of all n layers —
        # 95-layer deepseek saves 19+5 activations instead of 95.
        xs2 = jax.tree.map(
            lambda a: a.reshape(n1, n2, *a.shape[1:]), xs
        )

        @jax.checkpoint
        def outer(carry, xs_outer):
            return jax.lax.scan(body_fn, carry, xs_outer)

        (x, lb), cache_out = jax.lax.scan(outer, init, xs2)
        cache_out = jax.tree.map(
            lambda a: a.reshape(n1 * n2, *a.shape[2:]), cache_out
        )
    else:
        (x, lb), cache_out = jax.lax.scan(body_fn, init, xs)
    return x, cache_out, lb


def _remat_factors(n: int) -> tuple[int, int]:
    """Factor n = n1 * n2 with n2 as close to sqrt(n) as possible."""
    best = (n, 1)
    for d in range(2, int(n ** 0.5) + 1):
        if n % d == 0:
            best = (n // d, d)
    return best


# ----------------------------------------------------------------------
# Entry points
# ----------------------------------------------------------------------


def _positions(cfg: ModelConfig, batch: dict, b: int, s: int) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    p = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.mrope_sections:
        p = jnp.broadcast_to(p[..., None], (b, s, len(cfg.mrope_sections)))
    return p


def _inputs_to_x(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    if "embeds" in batch:
        return batch["embeds"].astype(cfg.jdtype)
    return L.embed_tokens(cfg, params["embed"], batch["tokens"])


def _backbone(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    caches=None,
    pos=None,
    impl=None,
    page_table=None,
    paged_impl=None,
):
    groups = cfg.layer_groups()
    lb_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, g in enumerate(groups):
        c_in = caches[i] if caches is not None else None
        x, c_out, lb = _run_group(
            cfg, g, params["groups"][g.param_key], x, positions,
            mode=mode, cache=c_in, pos=pos, impl=impl,
            page_table=page_table, paged_impl=paged_impl,
        )
        new_caches.append(c_out)
        lb_total = lb_total + lb
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_caches, lb_total


def forward_train(
    cfg: ModelConfig, params: dict, batch: dict, *, impl: str | None = None
):
    """batch: {"tokens" | "embeds", "labels" (B,S) int32} -> (loss, metrics)."""
    x = _inputs_to_x(cfg, params, batch)
    b, s, _ = x.shape
    positions = _positions(cfg, batch, b, s)
    x, _, lb = _backbone(cfg, params, x, positions, mode="train", impl=impl)
    logits = L.lm_logits(cfg, params["head"], params["embed"], x)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    # Gold logit via a fused indicator reduce, NOT take_along_axis: a gather
    # along the model-sharded vocab axis would force GSPMD to all-gather the
    # full (B, S, V) logits on every device.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((logz - gold) * mask).sum() / jnp.clip(mask.sum(), 1.0)
    loss = nll + 0.01 * lb
    return loss, {"nll": nll, "lb_loss": lb}


def prefill(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    impl: str | None = None,
):
    """Full-sequence inference pass; returns (last-token logits, caches)."""
    x = _inputs_to_x(cfg, params, batch)
    b, s, _ = x.shape
    positions = _positions(cfg, batch, b, s)
    x, caches, _ = _backbone(
        cfg, params, x, positions, mode="prefill", impl=impl
    )
    logits = L.lm_logits(cfg, params["head"], params["embed"], x[:, -1])
    return logits, caches


def prefill_paged(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    plens: jax.Array,
    caches: list,
    page_rows: jax.Array,
    *,
    prefix_rows: jax.Array | None = None,
    prefix_lens: jax.Array | None = None,
    full_tokens: jax.Array | None = None,
    full_plens: jax.Array | None = None,
    impl: str | None = None,
    sampler: dict | None = None,
    sampler_candidates: int | None = None,
):
    """Batched bucketed prefill into a block-paged KV cache.

    One jit'd full-sequence pass over a whole admission group — no
    per-token loop and no per-request call: ``tokens`` (N, S) holds N
    prompts right-padded to the shared page-multiple bucket ``S``;
    ``plens`` (N,) int32 the real prompt lengths; ``page_rows``
    (N, S//page) each request's physical pages (entries past
    ``pages_for_len(plen)`` point at the trash page 0, so padding keys
    scatter there and real pages stay untouched). The causal schedule
    runs inside ``apply_attention`` prefill mode; keys written for padded
    positions land beyond ``plen`` in logical order and are masked by
    every decode read.

    Cache-aware *partial* prefill (prefix cache hits): when
    ``prefix_lens`` (N,) is given, ``tokens``/``plens`` carry only each
    request's *uncached suffix* (page-aligned — hits cover full pages)
    and ``prefix_rows`` (N, P_pre) the physical pages already holding
    its prefix K/V (trash-padded past the real prefix). Suffix queries
    run at absolute positions ``prefix_len + i`` and attend the full
    prefix through the page table (``apply_attention`` mode
    ``prefill_prefix``); only suffix K/V is computed and scattered —
    shared prefix pages are never written. ``full_tokens``/``full_plens``
    (the whole prompt, any bucket) seed the sampler's presence buffer,
    which must cover cached prefix tokens too.

    Returns (logits at each request's last real token (N, V), updated
    paged caches) — or, when ``sampler`` is given (the engine's packed
    per-request sampling params, ``repro.serving.sampling``), the fused
    first-token sample: (token ids (N,) int32, caches, presence), so the
    host syncs N ints instead of (N, V) logits.
    """
    x = _inputs_to_x(cfg, params, {"tokens": tokens})
    b, s, _ = x.shape
    if prefix_lens is None:
        positions = _positions(cfg, {}, b, s)
        x, kv, _ = _backbone(
            cfg, params, x, positions, mode="prefill", impl=impl
        )
    else:
        positions = prefix_lens[:, None] + jnp.arange(s)[None, :]
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(
                positions[..., None], (b, s, len(cfg.mrope_sections))
            )
        x, kv, _ = _backbone(
            cfg, params, x, positions, mode="prefill_prefix",
            caches=caches, pos=prefix_lens, page_table=prefix_rows,
            impl=impl,
        )
    # (N, d) hidden state at each request's last *real* prompt token
    xe = jnp.take_along_axis(x, (plens - 1)[:, None, None], axis=1)[:, 0]
    logits = L.lm_logits(cfg, params["head"], params["embed"], xe)

    new_caches = []
    for pool, fresh in zip(caches, kv):
        def scat(buf, kvs):
            count, _, page, hk, d = buf.shape
            fb = kvs.reshape(count, b, s // page, page, hk, d)
            # page_rows (N, P): scatter every request's pages in one shot.
            # Rows collide only on the shared trash page 0 (padding), where
            # last-write-wins is fine — trash is masked by logical position
            # on every read.
            # pin the pool layout through the scatter: the page axes stay
            # replicated, the head axis keeps its model-axis shard
            return L.constrain_paged_pool(
                cfg, buf.at[:, page_rows].set(fb.astype(buf.dtype))
            )

        new_caches.append(jax.tree.map(scat, pool, fresh))
    if sampler is None:
        return logits, new_caches
    # in-function import: repro.serving imports this module at init time
    from repro.serving import sampling as sampling_lib

    # partial prefill: presence must be seeded from the WHOLE prompt
    # (cached prefix included), not just the suffix this call computed
    ptoks = tokens if full_tokens is None else full_tokens
    pplens = plens if full_plens is None else full_plens
    toks, presence = sampling_lib.sample_prefill(
        logits, ptoks, pplens, sampler, valid_vocab=cfg.vocab_size,
        candidates=sampler_candidates,
    )
    return toks, new_caches, presence


def decode_step(
    cfg: ModelConfig,
    params: dict,
    caches: list,
    tokens: jax.Array,
    pos: jax.Array,
    *,
    impl: str | None = None,
):
    """One decode step. tokens (B,) int32; pos () int32. Returns
    (logits (B, V), new caches)."""
    x = L.embed_tokens(cfg, params["embed"], tokens[:, None])
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(
            positions[..., None], (b, 1, len(cfg.mrope_sections))
        )
    x, new_caches, _ = _backbone(
        cfg, params, x, positions, mode="decode", caches=caches, pos=pos,
        impl=impl,
    )
    logits = L.lm_logits(cfg, params["head"], params["embed"], x[:, 0])
    return logits, new_caches


@hot_path
def decode_step_paged(
    cfg: ModelConfig,
    params: dict,
    caches: list,
    tokens: jax.Array,
    positions: jax.Array,
    page_table: jax.Array,
    *,
    impl: str | None = None,
    paged_impl: str | None = None,
    sampler: dict | None = None,
    sampler_candidates: int | None = None,
):
    """Slot-indexed decode step over a block-paged KV cache.

    tokens (B,) int32 one token per slot; positions (B,) int32 *ragged*
    per-slot write positions; page_table (B, P) int32 logical -> physical
    page map. Idle slots pass position 0 with an all-trash page row.
    ``paged_impl`` picks the paged attention read ("gather" jnp reference
    vs the "pallas"/"interpret" page-pool kernel). Returns
    (logits (B, V), new caches) — or, when ``sampler`` is given, the
    fused logits->token sample over every slot (ragged occupancy rides
    along: idle slots' samples are ignored host-side):
    (token ids (B,) int32, caches, presence). Either way one host sync
    per step suffices.
    """
    x = L.embed_tokens(cfg, params["embed"], tokens[:, None])
    b = x.shape[0]
    pos2 = positions[:, None]
    if cfg.mrope_sections:
        pos2 = jnp.broadcast_to(
            pos2[..., None], (b, 1, len(cfg.mrope_sections))
        )
    x, new_caches, _ = _backbone(
        cfg, params, x, pos2, mode="decode_paged", caches=caches,
        pos=positions, page_table=page_table, impl=impl,
        paged_impl=paged_impl,
    )
    logits = L.lm_logits(cfg, params["head"], params["embed"], x[:, 0])
    if sampler is None:
        return logits, new_caches
    from repro.serving import sampling as sampling_lib

    toks, presence = sampling_lib.sample_decode(
        logits, sampler, valid_vocab=cfg.vocab_size,
        candidates=sampler_candidates,
    )
    return toks, new_caches, presence
