"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block in JAX.

Chunked SSD for training/prefill (quadratic within a chunk, linear across
chunks) and the O(1) recurrent step for decode. Input/output projections are
the GEMMs the paper's technique applies to — they carry the Pixelfly
sparse+low-rank parameterization when ``cfg.sparse`` is set; the SSD scan
itself is an activation recurrence with no weight GEMM (butterfly
inapplicable there, see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.pixelfly import LinearSpec, apply_linear, init_linear

__all__ = ["SsmSpec", "init_ssm", "apply_ssm_train", "apply_ssm_decode", "init_ssm_cache"]


@dataclasses.dataclass(frozen=True)
class SsmSpec:
    cfg: ModelConfig

    @property
    def d_inner(self) -> int:
        return self.cfg.d_inner

    @property
    def heads(self) -> int:
        return self.cfg.ssm_heads

    @property
    def conv_dim(self) -> int:
        c = self.cfg
        return self.d_inner + 2 * c.ssm_groups * c.ssm_state

    @property
    def in_dim(self) -> int:
        # z, xBC, dt
        return 2 * self.d_inner + 2 * self.cfg.ssm_groups * self.cfg.ssm_state + self.heads

    def _lin(self, din: int, dout: int) -> LinearSpec:
        c = self.cfg
        if c.sparse and din % c.sparse_block == 0 and dout % c.sparse_block == 0:
            return LinearSpec.pixelfly(
                din,
                dout,
                c.sparse_density,
                block=c.sparse_block,
                lowrank_frac=c.lowrank_frac,
                dtype=c.jdtype,
            )
        return LinearSpec.dense(din, dout, dtype=c.jdtype)

    @property
    def in_proj(self) -> LinearSpec:
        return self._lin(self.cfg.d_model, self.in_dim)

    @property
    def out_proj(self) -> LinearSpec:
        return self._lin(self.d_inner, self.cfg.d_model)


def init_ssm(key: jax.Array, spec: SsmSpec) -> dict:
    c = spec.cfg
    k1, k2, k3 = jax.random.split(key, 3)
    h = spec.heads
    dt = jnp.exp(
        jax.random.uniform(k3, (h,), jnp.float32)
        * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    return {
        "in_proj": init_linear(k1, spec.in_proj),
        "out_proj": init_linear(k2, spec.out_proj),
        "conv_w": (
            jax.random.normal(k2, (c.ssm_conv, spec.conv_dim), jnp.float32)
            / math.sqrt(c.ssm_conv)
        ).astype(c.jdtype),
        "conv_b": jnp.zeros((spec.conv_dim,), c.jdtype),
        "A_log": jnp.log(
            jnp.arange(1, h + 1, dtype=jnp.float32)
        ),  # A in [-1, -h]
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse softplus
        "norm": jnp.ones((spec.d_inner,), jnp.float32),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., L) -> (..., L, L) with out[i, j] = sum_{j<k<=i} x[k], -inf above diag."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) fp32 (post-softplus)
    A: jax.Array,  # (H,) fp32, negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    chunk: int,
):
    """SSD: y_t = C_t^T (sum_{s<=t} prod(decay) dt_s B_s x_s).

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # dt=0 padding is exact: decay exp(0*A)=1, input dt*B*x = 0.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    c = s_pad // chunk
    xc = (x * dt[..., None]).reshape(b, c, chunk, h, p).astype(jnp.float32)
    dA = (dt * A[None, None, :]).reshape(b, c, chunk, h)  # (b,c,l,h)
    Bc = Bm.reshape(b, c, chunk, n).astype(jnp.float32)
    Cc = Cm.reshape(b, c, chunk, n).astype(jnp.float32)

    dA_cs = jnp.cumsum(dA, axis=2)  # (b,c,l,h)

    # --- intra-chunk (block-diagonal) term
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (b,c,h,l,l)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)  # (b,c,l,s)
    y_diag = jnp.einsum("bchls,bcls,bcshp->bclhp", L, scores, xc)

    # --- chunk-final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xc)

    # --- inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b,c,h)

    def scan_fn(prev, inp):
        dec, st = inp  # dec (b,h), st (b,h,p,n)
        new = prev * dec[..., None, None] + st
        return new, prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)),
    )
    prev_states = prev_states.swapaxes(0, 1)  # (b,c,h,p,n): state entering chunk

    # --- inter-chunk output term
    state_decay = jnp.exp(dA_cs)  # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s_pad, h, p)
    return y[:, :s], final_state


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x (B, S, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return y + b[None, None, :]


def _split_zxbcdt(spec: SsmSpec, zxbcdt: jax.Array):
    c = spec.cfg
    di, n = spec.d_inner, c.ssm_groups * c.ssm_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + spec.conv_dim]
    dt = zxbcdt[..., di + spec.conv_dim :]
    return z, xBC, dt


def _gated_norm(scale: jax.Array, y: jax.Array, z: jax.Array, eps: float):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def apply_ssm_train(
    spec: SsmSpec,
    params: dict,
    x: jax.Array,
    *,
    impl: str | None = None,
    return_state: bool = False,
):
    """Full-sequence forward. x (B, S, D) -> (y, cache-or-None)."""
    c = spec.cfg
    b, s, _ = x.shape
    h, p, n = spec.heads, c.ssm_head_dim, c.ssm_state
    zxbcdt = apply_linear(spec.in_proj, params["in_proj"], x, impl=impl)
    z, xBC_pre, dt = _split_zxbcdt(spec, zxbcdt)
    xBC = _causal_conv(xBC_pre, params["conv_w"], params["conv_b"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs = xBC[..., : spec.d_inner].reshape(b, s, h, p)
    Bm = xBC[..., spec.d_inner : spec.d_inner + n]
    Cm = xBC[..., spec.d_inner + n :]
    dtf = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    A = -jnp.exp(params["A_log"])
    y, final_state = _ssd_chunked(xs, dtf, A, Bm, Cm, c.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, spec.d_inner).astype(x.dtype)
    y = _gated_norm(params["norm"], y, z, c.norm_eps)
    out = apply_linear(spec.out_proj, params["out_proj"], y, impl=impl)
    cache = None
    if return_state:
        cache = {
            "conv": xBC_pre[:, -(c.ssm_conv - 1) :, :],
            "state": final_state,
        }
    return out, cache


def init_ssm_cache(spec: SsmSpec, batch: int, dtype) -> dict:
    c = spec.cfg
    return {
        "conv": jnp.zeros((batch, c.ssm_conv - 1, spec.conv_dim), dtype),
        "state": jnp.zeros(
            (batch, spec.heads, c.ssm_head_dim, c.ssm_state), jnp.float32
        ),
    }


def apply_ssm_decode(
    spec: SsmSpec,
    params: dict,
    x: jax.Array,
    cache: dict,
    *,
    impl: str | None = None,
):
    """One-token step. x (B, 1, D) -> (y (B,1,D), new cache)."""
    c = spec.cfg
    b = x.shape[0]
    h, p, n = spec.heads, c.ssm_head_dim, c.ssm_state
    zxbcdt = apply_linear(spec.in_proj, params["in_proj"], x, impl=impl)
    z, xBC, dt = _split_zxbcdt(spec, zxbcdt)
    # conv cache: (B, K-1, conv_dim) of pre-conv activations
    window = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B, K, C)
    new_conv = window[:, 1:, :]
    w = params["conv_w"]
    y = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"][None, :]
    xBC1 = jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)[:, None, :]
    xs = xBC1[..., : spec.d_inner].reshape(b, h, p)
    Bm = xBC1[:, 0, spec.d_inner : spec.d_inner + n].astype(jnp.float32)
    Cm = xBC1[:, 0, spec.d_inner + n :].astype(jnp.float32)
    dtf = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + params["dt_bias"][None, :]
    )  # (B, H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dtf * A[None, :])  # (B, H)
    dBx = jnp.einsum(
        "bh,bn,bhp->bhpn", dtf, Bm, xs.astype(jnp.float32)
    )
    state = cache["state"] * dA[..., None, None] + dBx
    yh = jnp.einsum("bhpn,bn->bhp", state, Cm)
    yh = yh + params["D"][None, :, None] * xs.astype(jnp.float32)
    yh = yh.reshape(b, 1, spec.d_inner).astype(x.dtype)
    yh = _gated_norm(params["norm"], yh, z, c.norm_eps)
    out = apply_linear(spec.out_proj, params["out_proj"], yh, impl=impl)
    return out, {"conv": new_conv, "state": state}
