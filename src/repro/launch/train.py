"""Training launcher.

CPU-scale real training (runs here) and the entry point a TPU cluster
would use (same code path; the mesh and strategy come from flags).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.training.data import EmbedsWrapper, SyntheticLM, TextFileLM
from repro.training.loop import TrainConfig, Trainer
from repro.training.optimizer import OptConfig


def build_data(cfg, args):
    if args.data_file:
        src = TextFileLM(args.data_file, args.seq, args.batch, seed=args.seed)
    else:
        src = SyntheticLM(
            min(cfg.vocab_size, 512) if args.smoke else cfg.vocab_size,
            args.seq,
            args.batch,
            seed=args.seed,
        )
    if not cfg.embed_inputs:
        src = EmbedsWrapper(
            src, cfg.d_model, n_pos_streams=len(cfg.mrope_sections)
        )
    return src


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--sparse", action="store_true", help="pixelfly model")
    ap.add_argument("--density", type=float, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-file", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", choices=["tp", "fsdp"], default="fsdp")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = registry.get_smoke(args.arch, sparse=args.sparse)
    else:
        cfg = registry.get(args.arch, sparse=args.sparse, density=args.density)
    if args.density is not None:
        cfg = cfg.replace(sparse_density=args.density)

    mesh = (
        make_production_mesh()
        if args.production_mesh
        else make_local_mesh()
    )
    opt = OptConfig(
        lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        compress_grads=args.compress_grads,
    )
    data = build_data(cfg, args)
    trainer = Trainer(
        cfg,
        opt,
        data,
        mesh,
        TrainConfig(
            steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, seed=args.seed,
        ),
        strategy=args.strategy,
    )
    hist = trainer.run()
    if hist:
        print(
            f"final loss {hist[-1]['loss']:.4f} after {trainer.step} steps "
            f"({trainer.straggler_events} straggler events)"
        )
    trainer.checkpoint()


if __name__ == "__main__":
    main()
