"""Serving launcher: thin CLI over the continuous-batching engine.

CPU-scale demo (smoke configs) and the TPU entry point (full configs via
the production mesh). Requests flow through ``repro.serving.Engine``:
batched bucketed prefill (one jit'd call per same-bucket admission
group) straight into the block-paged KV cache, one jit'd decode step per
token over all slots, admission/eviction per step.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16

``Server`` below is the pre-engine fixed-batch reference path (prefills
token-by-token through the decode step); it is kept as the numerics
oracle for tests and as the baseline the serving benchmark measures the
engine against.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed import sharding
from repro.launch.mesh import (
    make_local_mesh,
    make_production_mesh,
    make_tp_mesh,
)
from repro.models import transformer as T
from repro.serving import (
    Engine,
    EngineConfig,
    SamplingParams,
    ScheduleParams,
)
from repro.serving.router import ReplicaRouter


class Server:
    """Fixed-batch LM server (reference): prefill once, then step the
    decode cache. Superseded by ``repro.serving.Engine`` for serving."""

    def __init__(self, cfg, mesh, *, strategy: str = "fsdp", seed: int = 0):
        self.cfg, self.mesh = cfg, mesh
        st = sharding.Strategy(mesh, strategy)
        self.cfg = cfg = cfg.replace(tp_size=st.tp_size, batch_axes=st.batch)
        with mesh:
            key = jax.random.PRNGKey(seed)
            pshape = jax.eval_shape(lambda k: T.init_model(k, cfg), key)
            psh = sharding.param_shardings(st, pshape)
            self.params = jax.jit(
                lambda k: T.init_model(k, cfg), out_shardings=psh
            )(key)
            self._decode = jax.jit(
                lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos)
            )
        self.st = st

    def generate(self, prompts: np.ndarray, gen_len: int) -> np.ndarray:
        """prompts: (B, P) int32 -> (B, gen_len) int32."""
        cfg = self.cfg
        b, plen = prompts.shape
        max_len = plen + gen_len + 1
        with self.mesh:
            caches = T.init_cache(cfg, b, max_len)
            # prefill token-by-token through the decode path keeps one code
            # path; a production server would jit T.prefill (we lower it in
            # the dry-run) — here prompt lengths are tiny.
            logits = None
            for i in range(plen):
                logits, caches = self._decode(
                    self.params, caches, jnp.asarray(prompts[:, i]),
                    jnp.asarray(i, jnp.int32),
                )
            out = []
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for j in range(gen_len):
                out.append(np.asarray(tok))
                logits, caches = self._decode(
                    self.params, caches, tok,
                    jnp.asarray(plen + j, jnp.int32),
                )
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)


def _write_obs(served, metrics, args) -> None:
    """Flush observability artifacts after drain: a Perfetto timeline
    (``--trace-out``) and/or a Prometheus snapshot (``--metrics-out``).
    ``served`` is the Engine or ReplicaRouter (both export the same
    ``export_perfetto(path)`` surface)."""
    if args.trace_out:
        n = served.export_perfetto(args.trace_out)
        print(f"wrote {n} trace events to {args.trace_out} "
              "(open at ui.perfetto.dev)")
    if args.metrics_out:
        from repro.obs.prom import write_snapshot

        write_snapshot(args.metrics_out, metrics)
        print(f"wrote Prometheus snapshot to {args.metrics_out}")


def _maybe_listen(served, args):
    """Start the live scrape endpoint (``--listen``) before draining.
    ``served`` is the Engine or ReplicaRouter; returns the running
    ``MetricsServer`` or None."""
    if not args.listen:
        return None
    from repro.obs.http import attach

    server = attach(served, args.listen)
    print(f"live telemetry at {server.url} "
          "(/metrics /healthz /vars /slo)")
    return server


def _shutdown_live(server, engines, args) -> None:
    """Stop the ``--listen`` endpoint and report flight-recorder
    incidents captured during the run."""
    if server is not None:
        server.stop()
    if args.flight_dir:
        n = sum(len(eng._flight.incidents) for eng in engines
                if eng._flight is not None)
        print(f"flight recorder: {n} incident bundle(s) "
              f"under {args.flight_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sparse", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests to submit")
    ap.add_argument("--slots", type=int, default=0,
                    help="engine cache slots (default: --batch)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="per-slot KV capacity (default: fits prompt+gen)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--lookahead", type=int, default=0,
                    help="admission lookahead window K (default: 2*slots)")
    ap.add_argument("--max-prefill-batch", type=int, default=0,
                    help="cap requests per jit'd prefill call (default: "
                         "slots; 1 = per-request admission baseline)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = disabled)")
    ap.add_argument("--repetition-penalty", type=float, default=1.0,
                    help="penalty on already-seen tokens (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed; request b uses seed+b")
    ap.add_argument("--sampler-candidates", type=int, default=64,
                    help="static top-C candidate cap for the fused "
                         "sampler (0 = exact full-vocab; top-k must "
                         "fit under it)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prompt-prefix reuse: admission maps "
                         "cached prefix pages into the new slot and "
                         "prefills only the uncached suffix")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="first N tokens of every synthetic prompt are "
                         "a common system prompt (demos --prefix-cache "
                         "hits; 0 = fully independent prompts)")
    ap.add_argument("--priority", type=int, default=0,
                    help="scheduling priority for the submitted batch "
                         "(higher admits first and may preempt lower)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="soft end-to-end deadline in seconds (0 = "
                         "none); reported as SLO attainment")
    ap.add_argument("--max-queue-wait", type=float, default=0.0,
                    help="give up (structured rejection) if not "
                         "admitted within this many seconds (0 = wait "
                         "forever)")
    ap.add_argument("--no-preemption", action="store_true",
                    help="disable priority preemption (host-memory "
                         "page swap)")
    ap.add_argument("--preempt-min-steps", type=int, default=4,
                    help="hysteresis: steps a sequence must run after "
                         "admit/resume before it can be preempted")
    ap.add_argument("--max-skips", type=int, default=64,
                    help="anti-starvation: after this many passes of "
                         "being admitted around, a waiting request "
                         "blocks later admissions until it fits "
                         "(0 disables aging)")
    ap.add_argument("--strategy", choices=["tp", "fsdp"], default="fsdp")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard weights AND the "
                         "paged KV pools over the model axis of a "
                         "(1, tp) device slice (implies --strategy tp)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel replicas: N complete engines on "
                         "disjoint (1, tp) slices behind a least-loaded "
                         "router (repro.serving.router)")
    ap.add_argument("--paged-impl", default=None,
                    choices=["gather", "pallas", "interpret"],
                    help="paged decode-attention read (default: pallas on "
                         "TPU, gather elsewhere)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--legacy-server", action="store_true",
                    help="use the fixed-batch reference Server instead")
    ap.add_argument("--trace", action="store_true",
                    help="enable span tracing (repro.obs) without "
                         "writing a file")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event / Perfetto JSON "
                         "timeline after draining (implies --trace); "
                         "open it at ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text-exposition snapshot "
                         "of the serving metrics after draining")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve live /metrics /healthz /vars /slo over "
                         "HTTP while running (port 0 = ephemeral; "
                         "implies --monitor 30)")
    ap.add_argument("--monitor", type=float, default=0.0, metavar="SECS",
                    help="rolling live-telemetry window in seconds "
                         "(0 = off; feeds /vars and the SLO monitor)")
    ap.add_argument("--slo-target", type=float, default=0.0,
                    help="SLO attainment objective, e.g. 0.99 (0 = "
                         "burn-rate monitor off)")
    ap.add_argument("--slo-fast-window", type=float, default=60.0,
                    help="fast burn-rate window in seconds")
    ap.add_argument("--slo-slow-window", type=float, default=300.0,
                    help="slow burn-rate window in seconds")
    ap.add_argument("--slo-shed", action="store_true",
                    help="shed lowest-priority queued requests while "
                         "the burn-rate state is CRITICAL (structured "
                         "rejections; off = monitor only)")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="arm the flight recorder: write incident "
                         "bundles (trace + metrics + config) under DIR "
                         "on step-time spikes, post-warmup compiles and "
                         "SLO CRITICAL transitions")
    args = ap.parse_args()

    cfg = (
        registry.get_smoke(args.arch, sparse=args.sparse)
        if args.smoke
        else registry.get(args.arch, sparse=args.sparse)
    )
    if not cfg.embed_inputs:
        raise SystemExit(
            f"{args.arch} has a stub modality frontend; serve the backbone "
            "via the dry-run (decode_32k) instead"
        )
    if args.tp > 1 and args.strategy != "tp":
        raise SystemExit(
            "--tp > 1 shards over the model axis: pass --strategy tp"
        )
    if args.production_mesh:
        mesh = make_production_mesh()
    elif args.tp > 1:
        mesh = make_tp_mesh(args.tp)
    else:
        mesh = make_local_mesh()
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len), dtype=np.int32
    )
    if args.shared_prefix:
        n = min(args.shared_prefix, args.prompt_len)
        prompts[:, :n] = prompts[0, :n]  # one system prompt for everyone

    # the paged cache covers attention families; SSM/hybrid state is
    # slot-indexed, not paged — serve those through the reference path
    has_ssm = any(g.kind == "ssm" for g in cfg.layer_groups())
    if has_ssm and not args.legacy_server:
        print(f"{args.arch} has SSM layers: using the fixed-batch Server "
              "(paged engine covers attention families)")
    sp0 = SamplingParams(
        temperature=args.temperature,
        top_k=args.top_k,
        top_p=args.top_p,
        repetition_penalty=args.repetition_penalty,
        seed=args.seed,
    )
    if args.legacy_server or has_ssm:
        if not sp0.is_plain:
            raise SystemExit(
                f"sampler '{sp0.kind}' needs the paged engine (in-jit "
                "sampling); the reference Server path is plain-greedy "
                "only"
            )
        server = Server(cfg, mesh, strategy=args.strategy)
        t0 = time.perf_counter()
        out = server.generate(prompts, args.gen)
        dt = time.perf_counter() - t0
        print(f"generated {out.shape} tokens in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print(out[:2])
        return

    max_len = args.max_len or (args.prompt_len + args.gen + 1)
    slo = None
    if args.slo_target:
        from repro.obs import SloConfig

        slo = SloConfig(
            target=args.slo_target,
            fast_window_s=args.slo_fast_window,
            slow_window_s=args.slo_slow_window,
            shed=args.slo_shed,
        )
    # --listen without an explicit window still needs live aggregation
    # behind /vars; the SLO monitor sizes its own window when set
    monitor = args.monitor if args.monitor else bool(args.listen)
    ecfg = EngineConfig(
        max_slots=args.slots or args.batch,
        max_len=max_len,
        lookahead=args.lookahead or None,
        max_prefill_batch=args.max_prefill_batch,
        sampler_candidates=args.sampler_candidates,
        max_skips=args.max_skips,
        prefix_cache=args.prefix_cache,
        preemption=not args.no_preemption,
        preempt_min_steps=args.preempt_min_steps,
        trace=bool(args.trace or args.trace_out),
        monitor=monitor,
        slo=slo,
        flight_dir=args.flight_dir,
    )
    schedule = ScheduleParams(
        priority=args.priority,
        deadline_s=args.deadline or None,
        max_queue_wait_s=args.max_queue_wait or None,
    )
    if args.replicas > 1:
        router = ReplicaRouter(
            cfg,
            replicas=args.replicas,
            tp=args.tp,
            engine_cfg=ecfg,
            strategy=args.strategy,
            paged_impl=args.paged_impl,
        )
        print(
            f"paged decode impl: {router.engines[0].paged_impl}, "
            f"sampler: {sp0.kind}, "
            f"{args.replicas} replicas x tp={args.tp}"
        )
        for b in range(args.batch):
            router.submit(
                prompts[b],
                args.gen,
                sampling=dataclasses.replace(sp0, seed=args.seed + b),
                schedule=schedule,
            )
        server = _maybe_listen(router, args)
        t0 = time.perf_counter()
        finished = router.drain()
        dt = time.perf_counter() - t0
        total = sum(len(f.tokens) for f in finished)
        s = router.stats_summary()
        per = [int(rep["requests_finished"]) for rep in s["per_replica"]]
        print(
            f"served {len(finished)} requests / {total} tokens in "
            f"{dt:.2f}s ({total / dt:.1f} tok/s end-to-end, "
            f"{s['decode_tok_s']:.1f} tok/s decode fleet-wide, "
            f"p50 {s['p50_token_latency_ms']:.1f}ms "
            f"p95 {s['p95_token_latency_ms']:.1f}ms; "
            f"per-replica finished: {per})"
        )
        _write_obs(router, router.merged_metrics(), args)
        _shutdown_live(server, router.engines, args)
        grid = np.stack(
            [f.tokens for f in sorted(finished, key=lambda f: f.uid)[:2]]
        )
        print(grid)
        return

    engine = Engine(
        cfg,
        mesh,
        strategy=args.strategy,
        engine_cfg=ecfg,
        paged_impl=args.paged_impl,
    )
    print(f"paged decode impl: {engine.paged_impl}, sampler: {sp0.kind}")
    for b in range(args.batch):
        # each request gets its own noise stream via a distinct seed
        engine.submit(
            prompts[b],
            args.gen,
            sampling=dataclasses.replace(sp0, seed=args.seed + b),
            schedule=schedule,
        )
    server = _maybe_listen(engine, args)
    t0 = time.perf_counter()
    finished = engine.drain()
    dt = time.perf_counter() - t0
    s = engine.stats_summary()
    total = sum(len(f.tokens) for f in finished)
    print(
        f"served {len(finished)} requests / {total} tokens in {dt:.2f}s "
        f"({total / dt:.1f} tok/s end-to-end, "
        f"{s['decode_tok_s']:.1f} tok/s decode, "
        f"p50 {s['p50_token_latency_ms']:.1f}ms "
        f"p95 {s['p95_token_latency_ms']:.1f}ms, "
        f"occupancy {s['mean_occupancy']:.2f}, "
        f"{s['mean_prefill_batch']:.1f} req/prefill)"
    )
    pre = s["preemption"]
    if pre["preemptions"] or s["rejected"]["total"] or args.deadline:
        print(
            f"scheduling: {pre['preemptions']} preemptions "
            f"({pre.get('out_bytes', 0)} bytes swapped out, "
            f"{pre.get('in_bytes', 0)} restored), "
            f"{s['rejected']['total']} rejected, "
            f"SLO attainment {s['slo']['attainment']:.0%} "
            f"({s['slo']['met']}/{s['slo']['with_deadline']}), "
            f"ttft p95 {s['ttft_ms']['p95_ms']:.1f}ms"
        )
    if args.prefix_cache:
        pc = s["prefix_cache"]
        print(
            f"prefix cache: {pc['hit_rate']:.0%} hit rate "
            f"({pc['hit_tokens']}/{pc['prompt_tokens']} prompt tokens, "
            f"{pc['hit_pages']} shared pages, "
            f"{pc['inserted_pages']} indexed, {pc['evicted_pages']} "
            f"evicted, {pc['cow_copies']} COW)"
        )
    _write_obs(engine, engine.metrics, args)
    _shutdown_live(server, [engine], args)
    grid = np.stack(
        [f.tokens for f in sorted(finished, key=lambda f: f.uid)[:2]]
    )
    print(grid)


if __name__ == "__main__":
    main()
