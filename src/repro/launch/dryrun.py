import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  2. lowers the right step function (train_step / prefill_step /
     serve_step) with full in/out shardings over ShapeDtypeStructs,
  3. compiles it, prints ``memory_analysis()`` (fits-per-device proof) and
     ``cost_analysis()``,
  4. runs the HLO roofline walker (repro.analysis.roofline) and emits the
     three roofline terms + MODEL_FLOPS ratio as JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --json out.json
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline
from repro.configs import registry
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.distributed import sharding
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.training.loop import make_train_step
from repro.training.optimizer import OptConfig


def _prep_cfg(cfg: ModelConfig, mesh, st: sharding.Strategy) -> ModelConfig:
    """Launcher-side knobs that depend on the mesh + strategy."""
    dp = mesh.size // (mesh.shape.get("model", 1) if st.kind == "tp" else 1)
    cfg = cfg.replace(tp_size=st.tp_size, batch_axes=st.batch)
    if cfg.moe_num_experts:
        cfg = cfg.replace(moe_routing_groups=dp)
    return cfg


def pick_microbatches(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    st: sharding.Strategy,
    *,
    target_tokens_per_device: int | None = None,
) -> int:
    """Gradient-accumulation factor: bound live activations to
    ~target tokens/device/microbatch. k must divide the per-data-shard
    batch so every microbatch stays evenly sharded."""
    if shape.kind != "train":
        return 1
    if target_tokens_per_device is None:
        target_tokens_per_device = int(
            os.environ.get("REPRO_MB_TARGET_TOKENS", 4096)
        )
    dp = 1
    for a in st.batch:
        dp *= mesh.shape[a]
    dp = min(dp, shape.global_batch)
    per_dev = shape.global_batch * shape.seq_len // dp
    want = max(1, -(-per_dev // target_tokens_per_device))
    per_shard_batch = max(1, shape.global_batch // dp)
    k = 1
    for d in range(1, per_shard_batch + 1):
        if per_shard_batch % d == 0 and d <= want:
            k = d
    return k


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    *,
    strategy: str = "tp",
    opt_cfg: OptConfig = OptConfig(),
):
    """Returns the lowered computation for one cell."""
    st = sharding.Strategy(mesh, strategy)
    cfg = _prep_cfg(cfg, mesh, st)
    batch_in = specs_lib.input_specs(cfg, shape)

    with mesh:
        if shape.kind == "train":
            state = specs_lib.state_specs(cfg, opt_cfg)
            state_sh = {
                "params": sharding.param_shardings(st, state["params"]),
                "opt": {
                    "step": NamedSharding(mesh, P()),
                    "mu": sharding.param_shardings(st, state["opt"]["mu"]),
                    "nu": sharding.param_shardings(st, state["opt"]["nu"]),
                },
            }
            batch_sh = sharding.named(st, sharding.batch_specs(st, batch_in))
            k = pick_microbatches(cfg, shape, mesh, st)
            fn = make_train_step(cfg, opt_cfg, microbatches=k)
            lowered = jax.jit(
                fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state, batch_in)
            return lowered

        params = specs_lib.params_specs(cfg)
        params_sh = sharding.param_shardings(st, params)
        if shape.kind == "prefill":
            batch_sh = sharding.named(st, sharding.batch_specs(st, batch_in))

            def prefill_step(p, batch):
                logits, caches = T.prefill(cfg, p, batch)
                return logits, caches

            lowered = jax.jit(
                prefill_step, in_shardings=(params_sh, batch_sh)
            ).lower(params, batch_in)
            return lowered

        # decode
        caches = specs_lib.decode_cache_specs(cfg, shape)
        cache_sh = sharding.named(st, sharding.cache_specs(st, caches))
        tok_sh = sharding.named(
            st, sharding.batch_specs(st, batch_in)["tokens"]
        )

        def serve_step(p, c, tokens, pos):
            return T.decode_step(cfg, p, c, tokens, pos)

        lowered = jax.jit(
            serve_step,
            in_shardings=(
                params_sh,
                cache_sh,
                tok_sh,
                NamedSharding(mesh, P()),
            ),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        ).lower(
            params, caches, batch_in["tokens"], batch_in["pos"]
        )
        return lowered


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    sparse: bool = True,
    density: float | None = None,
    verbose: bool = True,
    strategy: str | None = None,
    overrides: dict | None = None,
) -> dict:
    shape = SHAPES[shape_name]
    cfg = registry.get(arch, sparse=sparse, density=density, **(overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    strategy = strategy or registry.DEFAULT_STRATEGY.get(arch, "tp")

    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, strategy=strategy)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # jax 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    cost = roofline.analyze_hlo(hlo)
    terms = roofline.roofline_terms(cost)

    n_tokens = (
        shape.global_batch * shape.seq_len
        if shape.kind in ("train", "prefill")
        else shape.global_batch
    )
    mflops = roofline.model_flops(
        cfg, n_tokens, backward=(shape.kind == "train")
    )
    hlo_flops_global = cost.flops * n_chips
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy,
        "sparse": sparse,
        "density": cfg.sparse_density if sparse else 1.0,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
        "xla_cost_flops_per_device": ca.get("flops"),
        "hlo_flops_per_device": cost.flops,
        "hlo_bytes_per_device": cost.bytes_accessed,
        "collective_bytes_per_device": cost.total_collective_bytes,
        "collective_breakdown": cost.collective_bytes,
        **terms,
        "model_flops_global": mflops,
        "useful_flops_ratio": (
            mflops / hlo_flops_global if hlo_flops_global else 0.0
        ),
    }
    if verbose:
        print(f"== {arch} x {shape_name} [{result['mesh']}] "
              f"{'pixelfly' if sparse else 'dense'} ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis flops/device (XLA, loop-bodies-once): {ca.get('flops')}")
        print(f"  walker flops/device {cost.flops:.3e}  bytes {cost.bytes_accessed:.3e}  "
              f"collective {cost.total_collective_bytes:.3e}")
        print(f"  terms: compute {terms['compute_s']*1e3:.2f}ms  "
              f"memory {terms['memory_s']*1e3:.2f}ms  "
              f"collective {terms['collective_s']*1e3:.2f}ms  "
              f"-> {terms['bottleneck']}-bound")
        print(f"  MODEL_FLOPS/HLO_FLOPS (useful ratio): {result['useful_flops_ratio']:.3f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dense", action="store_true", help="dense baseline (no pixelfly)")
    ap.add_argument("--strategy", choices=["tp", "fsdp"], default=None)
    ap.add_argument("--density", type=float, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in registry.ARCH_NAMES:
            for sh in registry.shapes_for(a, sparse=not args.dense):
                cells.append((a, sh.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results, failures = [], []
    for arch, sh in cells:
        for mp in meshes:
            try:
                results.append(
                    run_cell(
                        arch, sh, multi_pod=mp,
                        sparse=not args.dense, density=args.density,
                        strategy=args.strategy,
                    )
                )
            except Exception as e:  # noqa: BLE001 - report and continue
                traceback.print_exc()
                failures.append({"arch": arch, "shape": sh, "multi_pod": mp,
                                 "error": repr(e), "ok": False})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results + failures, f, indent=1)
    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
