"""Production mesh definitions (multi-pod dry-run §0/§1).

Defined as functions so importing this module never touches jax device
state (device count is locked on first backend init).
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_tp_mesh",
    "fsdp_axes",
    "MODEL_AXIS",
]

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_tp_mesh(tp: int):
    """A ``(1, tp)`` slice over the first ``tp`` devices: the serving
    engine's tensor-parallel mesh. Unlike ``make_local_mesh`` it does
    not claim every device — data parallelism for serving is replica
    routing over disjoint slices (``repro.serving.router``), never a
    batch-sharded step, so one engine takes exactly ``tp`` devices."""
    devs = jax.devices()
    if len(devs) < tp:
        raise ValueError(f"tp={tp} needs {tp} devices, have {len(devs)}")
    from jax.sharding import Mesh

    return Mesh(np.asarray(devs[:tp]).reshape(1, tp), ("data", "model"))


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Axes parameters/optimizer state are additionally sharded over
    (ZeRO-3): the pod axis (if present) plus the data axis."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def batch_axes(mesh) -> tuple[str, ...]:
    return fsdp_axes(mesh)
