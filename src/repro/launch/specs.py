"""ShapeDtypeStruct stand-ins for every model input (dry-run §2).

``input_specs(cfg, shape)`` returns the *data* inputs for the step kind
(train batch / prefill batch / decode cache+token), weak-type-correct and
shardable, with zero device allocation. ``state_specs`` /
``decode_state_specs`` build the parameter/optimizer/cache stand-ins via
``jax.eval_shape`` on the real initializers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer as T
from repro.training.optimizer import OptConfig, init_opt_state

__all__ = ["input_specs", "state_specs", "decode_cache_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Batch-input ShapeDtypeStructs for one (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch: dict = {}
        if cfg.embed_inputs:
            batch["tokens"] = _sds((b, s), jnp.int32)
        else:
            batch["embeds"] = _sds((b, s, cfg.d_model), cfg.jdtype)
        if cfg.mrope_sections:
            batch["positions"] = _sds(
                (b, s, len(cfg.mrope_sections)), jnp.int32
            )
        if shape.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
        return batch
    if shape.kind == "decode":
        return {
            "tokens": _sds((b,), jnp.int32),
            "pos": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: T.init_model(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )


def state_specs(cfg: ModelConfig, opt_cfg: OptConfig):
    p = params_specs(cfg)
    opt = jax.eval_shape(lambda q: init_opt_state(opt_cfg, q), p)
    return {"params": p, "opt": opt}


def decode_cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
