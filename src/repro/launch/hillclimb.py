import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""§Perf hillclimb driver: re-lower one cell with a named variant and
append (variant, roofline terms, memory) to perf_log.json — the
hypothesis -> change -> measure -> validate loop's bookkeeping.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch kimi-k2-1t-a32b \
      --shape train_4k --tag moe-anchor --note "EP anchor on capacity buffer"
"""

import argparse
import json

from repro.configs.base import SHAPES
from repro.configs import registry
from repro.launch.dryrun import run_cell

LOG = os.path.join(os.path.dirname(__file__), "..", "..", "..", "perf_log.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_NAMES)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--tag", required=True)
    ap.add_argument("--note", default="")
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--density", type=float, default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field=value (value eval'd)")
    ap.add_argument("--log", default=os.path.abspath(LOG))
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = eval(v)  # noqa: S307 - operator tool

    res = run_cell(
        args.arch, args.shape,
        multi_pod=args.multi_pod, sparse=not args.dense,
        density=args.density, strategy=args.strategy, overrides=overrides,
    )
    res["tag"] = args.tag
    res["note"] = args.note
    res["overrides"] = {k: repr(v) for k, v in overrides.items()}
    log = []
    if os.path.exists(args.log):
        log = json.load(open(args.log))
    log.append(res)
    json.dump(log, open(args.log, "w"), indent=1)
    print(f"[hillclimb] logged '{args.tag}' -> {args.log}")


if __name__ == "__main__":
    main()
