"""Pixelfly linear layer: ``W = γ·B + (1−γ)·U Vᵀ`` (paper §3.3 step 3).

Functional style: a frozen *spec* (static pattern, shapes) plus a params
pytree, so layers compose under ``jax.lax.scan`` over depth and shard with
plain NamedSharding rules. ``B`` is a flat block butterfly stored in BSR
layout (see ``repro.core.butterfly``); the low-rank factors U, V are
block-aligned (rank a multiple of the hardware block).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import budget as budget_lib
from repro.core import butterfly
from repro.kernels import ops

__all__ = ["LinearSpec", "init_linear", "apply_linear", "param_count"]


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Static description of one linear layer (dense or pixelfly)."""

    in_features: int
    out_features: int
    sparse: bool = False
    block: int = 128
    max_stride: int = 1
    rank: int = 128
    use_bias: bool = False
    dtype: Any = jnp.bfloat16

    def pattern(self) -> butterfly.FlatButterflyPattern:
        return butterfly.make_pattern(
            self.out_features,
            self.in_features,
            block=self.block,
            max_stride=self.max_stride,
        )

    @staticmethod
    def pixelfly(
        in_features: int,
        out_features: int,
        density: float,
        *,
        block: int = 128,
        lowrank_frac: float = 0.25,
        use_bias: bool = False,
        dtype: Any = jnp.bfloat16,
    ) -> "LinearSpec":
        """Build a spec from a density budget (§3.3 step 2 split).

        If the features are not multiples of ``block``, the block is halved
        (down to 8, the VPU sublane) until they are; if even 8 does not
        divide, the layer falls back to dense (the paper's recipe only
        covers block-aligned GEMMs).
        """
        while block > 8 and (in_features % block or out_features % block):
            block //= 2
        if in_features % block or out_features % block:
            return LinearSpec.dense(
                in_features, out_features, use_bias=use_bias, dtype=dtype
            )
        rank, max_stride = budget_lib.split_sparse_lowrank(
            out_features,
            in_features,
            density,
            block=block,
            lowrank_frac=lowrank_frac,
        )
        return LinearSpec(
            in_features=in_features,
            out_features=out_features,
            sparse=True,
            block=block,
            max_stride=max_stride,
            rank=rank,
            use_bias=use_bias,
            dtype=dtype,
        )

    @staticmethod
    def dense(
        in_features: int,
        out_features: int,
        *,
        use_bias: bool = False,
        dtype: Any = jnp.bfloat16,
    ) -> "LinearSpec":
        return LinearSpec(
            in_features=in_features,
            out_features=out_features,
            sparse=False,
            use_bias=use_bias,
            dtype=dtype,
        )


def init_linear(key: jax.Array, spec: LinearSpec) -> dict:
    """Initialize the parameter pytree for one linear layer."""
    if not spec.sparse:
        k1, _ = jax.random.split(key)
        std = 1.0 / math.sqrt(spec.in_features)
        p = {
            "w": (
                jax.random.normal(
                    k1, (spec.in_features, spec.out_features), jnp.float32
                )
                * std
            ).astype(spec.dtype)
        }
        if spec.use_bias:
            p["b"] = jnp.zeros((spec.out_features,), spec.dtype)
        return p

    pat = spec.pattern()
    kb, ku, kv, _ = jax.random.split(key, 4)
    # Effective fan-in of the sparse term is r*block, of the low-rank term
    # is `rank`; scale each so the summed output variance matches dense.
    std_b = 1.0 / math.sqrt(pat.r * spec.block)
    std_u = 1.0 / math.sqrt(spec.in_features)
    std_v = 1.0 / math.sqrt(max(1, spec.rank))
    p = {
        "blocks": (
            jax.random.normal(
                kb, (pat.nb_out, pat.r, spec.block, spec.block), jnp.float32
            )
            * std_b
        ).astype(spec.dtype),
        "U": (
            jax.random.normal(
                ku, (spec.in_features, spec.rank), jnp.float32
            )
            * std_u
        ).astype(spec.dtype),
        "V": (
            jax.random.normal(
                kv, (spec.out_features, spec.rank), jnp.float32
            )
            * std_v
        ).astype(spec.dtype),
        # γ is learnable (paper §3.3); stored in fp32 like other scalars.
        "gamma": jnp.asarray(0.5, jnp.float32),
    }
    if spec.use_bias:
        p["b"] = jnp.zeros((spec.out_features,), spec.dtype)
    return p


def apply_linear(
    spec: LinearSpec,
    params: dict,
    x: jax.Array,
    *,
    impl: str | None = None,
    cols: np.ndarray | None = None,
) -> jax.Array:
    """y = x @ W (+ bias). ``cols`` may be passed to avoid re-deriving the
    static pattern (e.g. when specs are built once at model setup)."""
    if not spec.sparse:
        y = jnp.einsum(
            "...i,io->...o", x, params["w"],
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
    else:
        if cols is None:
            cols = spec.pattern().cols
        g = params["gamma"].astype(jnp.float32)
        # §Perf C2 (refuted): the transposed-gather custom VJP reads
        # model-sharded blocks across shards (param all-gathers) — worse.
        # §Perf C3 (kept): autodiff backward with bf16 cotangents
        # (bsr_matmul_gather drops the f32 preferred type) + remat so the
        # r gathered activation copies are recomputed, not saved.
        cols_arr = jnp.asarray(cols)
        ys = jax.checkpoint(
            lambda xx, bb: ops.bsr_matmul(xx, bb, cols_arr, impl=impl)
        )(x, params["blocks"])
        # bf16 HLO values end-to-end (§Perf C3): MXU still accumulates
        # fp32 internally; cotangent collectives stay in the model dtype.
        xu = jnp.einsum("...i,ir->...r", x, params["U"])
        yl = jnp.einsum("...r,or->...o", xu, params["V"])
        y = (g * ys.astype(jnp.float32) + (1.0 - g) * yl.astype(jnp.float32)).astype(
            x.dtype
        )
    if spec.use_bias:
        y = y + params["b"].astype(y.dtype)
    return y


def param_count(spec: LinearSpec) -> int:
    if not spec.sparse:
        n = spec.in_features * spec.out_features
    else:
        pat = spec.pattern()
        n = pat.nnz + spec.rank * (spec.in_features + spec.out_features) + 1
    return n + (spec.out_features if spec.use_bias else 0)
