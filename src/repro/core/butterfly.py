"""Flat block butterfly index math (paper §3, Defs 3.1-3.4, App. A & I.4).

Everything in this module is *static* (numpy, no jax): patterns are fixed at
model-construction time — that is the whole point of the paper (static,
hardware-aligned sparsity; no mask search at training time).

Conventions
-----------
A flat block butterfly matrix of logical size ``(out, in)`` with hardware
block size ``b`` and maximum stride ``k`` (a power of 2, in *block* units) is
stored in a BSR-like layout:

  blocks : (nb_out, r, b, b)   dense parameter blocks
  cols   : (nb_out, r)         static int32 column-block index per slot

with ``r = 1 + log2(k)`` slots per block-row: the block diagonal (the ``I``
plus every factor's own diagonal collapse into one learned block) and one
slot per stride ``s ∈ {1, 2, 4, …, k/2}`` connecting block-row ``i`` to
block-column ``i XOR s`` — the fixed sparsity pattern of
``I + λ(B_2 + B_4 + … + B_k)`` (Def. 3.4).

Rectangular matrices are handled by "stretching" the square pattern
(App. I.4): the pattern is generated on the smallest power-of-two grid
covering both dimensions and indices are rescaled. Duplicate columns that
arise from down-scaling are kept (they add capacity on the same block — the
layout stays rectangular and static).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "log2_int",
    "next_pow2",
    "flat_butterfly_strides",
    "flat_butterfly_cols",
    "dense_mask_from_cols",
    "block_cover",
    "block_cover_density",
    "butterfly_factor_matrix",
    "max_stride_for_density",
    "density_for_max_stride",
    "FlatButterflyPattern",
    "make_pattern",
]


def log2_int(x: int) -> int:
    """Exact integer log2; raises if ``x`` is not a positive power of 2."""
    if x <= 0 or (x & (x - 1)) != 0:
        raise ValueError(f"{x} is not a positive power of two")
    return x.bit_length() - 1


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (x >= 1)."""
    if x < 1:
        raise ValueError("x must be >= 1")
    return 1 << (x - 1).bit_length()


def flat_butterfly_strides(max_stride: int) -> list[int]:
    """Strides (block units) of the flat butterfly of maximum stride ``k``.

    ``B_{2^t}^{(n)}`` contributes the stride ``2^{t-1}`` block diagonal, so a
    flat butterfly of maximum stride k has strides {1, 2, ..., k/2}
    (powers of two), plus the main diagonal.
    """
    if max_stride == 1:
        return []
    m = log2_int(max_stride)
    return [1 << t for t in range(m)]


def flat_butterfly_cols(
    nb_out: int, nb_in: int, max_stride: int
) -> np.ndarray:
    """Static block-column index table ``cols[nb_out, r]``.

    Square case (nb_out == nb_in == power of 2): cols[i] = [i, i^1, i^2, ...].
    Rectangular / non-pow2 case: generate on grid ``g = next_pow2(max(nb))``
    and rescale rows/cols (App. I.4 "stretch").
    """
    if nb_out < 1 or nb_in < 1:
        raise ValueError("need at least one block in each dimension")
    g = next_pow2(max(nb_out, nb_in))
    max_stride = min(max_stride, g)
    strides = flat_butterfly_strides(max_stride)
    r = 1 + len(strides)
    cols = np.empty((nb_out, r), dtype=np.int32)
    for i in range(nb_out):
        # Stretch the out-row index onto the square pow2 grid.
        gi = i * g // nb_out
        cs = [gi] + [gi ^ s for s in strides]
        # Map square-grid columns back to the input block grid.
        cols[i] = [c * nb_in // g for c in cs]
    return cols


def dense_mask_from_cols(
    nb_out: int, nb_in: int, cols: np.ndarray, b: int
) -> np.ndarray:
    """Materialize the dense {0,1} mask (out, in) — for tests/reference only."""
    mask = np.zeros((nb_out * b, nb_in * b), dtype=np.float32)
    for i in range(nb_out):
        for j in cols[i]:
            mask[i * b : (i + 1) * b, j * b : (j + 1) * b] = 1.0
    return mask


def block_cover(mask: np.ndarray, b1: int, b2: int) -> np.ndarray:
    """(b1, b2)-block cover of a sparse mask (Def. A.1).

    Divide ``mask`` into b1 x b2 blocks; a block of the cover is all-ones iff
    any entry of the original block is nonzero.
    """
    m, n = mask.shape
    if m % b1 or n % b2:
        raise ValueError("mask dims must be divisible by block dims")
    blk = mask.reshape(m // b1, b1, n // b2, b2)
    any_nz = (blk != 0).any(axis=(1, 3))
    return np.repeat(np.repeat(any_nz, b1, axis=0), b2, axis=1).astype(
        mask.dtype
    )


def block_cover_density(mask: np.ndarray, b: int) -> float:
    """Fraction of elements *accessed* on a block-``b`` device (Table 7)."""
    cover = block_cover(mask, b, b)
    return float((cover != 0).mean())


def butterfly_factor_matrix(
    n: int, k: int, rng: np.random.Generator, block: int = 1
) -> np.ndarray:
    """Dense materialization of a random block butterfly factor matrix
    ``B_k^{(n, b)}`` (Def. 3.2) — used by the flat-vs-product benchmark and
    expressiveness tests. ``n`` is in block units; returned matrix is
    ``(n*block, n*block)``.
    """
    if k < 2:
        raise ValueError("stride k must be >= 2")
    out = np.zeros((n * block, n * block), dtype=np.float64)
    half = k // 2
    # Nonzero block positions of B_k are (i, i) and (i, i XOR half) within
    # each aligned k-block.
    for i in range(n):
        base = (i // k) * k
        j2 = base + ((i - base) ^ half)
        for j in (i, j2):
            out[
                i * block : (i + 1) * block, j * block : (j + 1) * block
            ] = rng.standard_normal((block, block)) / math.sqrt(2 * block)
    return out


def density_for_max_stride(nb_in: int, max_stride: int, b: int, n_in: int) -> float:
    """Element density of a flat block butterfly with the given max stride."""
    r = 1 + len(flat_butterfly_strides(max_stride))
    return r * b / n_in


def max_stride_for_density(
    n_in: int, b: int, density: float
) -> int:
    """Largest power-of-2 max stride whose flat butterfly fits ``density``.

    Inverts density = (1 + log2 k) * b / n_in (§3.3 step 2: "pick the maximum
    stride of the flat block butterfly to fill up the budget"). Always
    returns at least stride 1 (block diagonal only).
    """
    nb_in = max(1, n_in // b)
    g = next_pow2(nb_in)
    slots = max(1, int(density * n_in / b))  # total block slots per row
    k = 1 << min(slots - 1, log2_int(g))
    return max(1, k)


def transpose_tables(
    cols: np.ndarray, nb_in: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Static transposed-pattern tables for the BSR backward pass.

    For each *input* block j, the list of (out-block i, slot t) pairs with
    ``cols[i, t] == j``, padded to the max fan-in. Returns
    (src_i, src_t, valid), each (nb_in, w). The transposed flat butterfly
    is itself a flat butterfly (XOR is an involution), so w == r for square
    patterns; rectangular stretches give ragged fan-in, hence the padding.
    """
    nb_out, r = cols.shape
    lists: list[list[tuple[int, int]]] = [[] for _ in range(nb_in)]
    for i in range(nb_out):
        for t in range(r):
            lists[int(cols[i, t])].append((i, t))
    w = max(1, max(len(l) for l in lists))
    src_i = np.zeros((nb_in, w), np.int32)
    src_t = np.zeros((nb_in, w), np.int32)
    valid = np.zeros((nb_in, w), np.float32)
    for j, l in enumerate(lists):
        for u, (i, t) in enumerate(l):
            src_i[j, u] = i
            src_t[j, u] = t
            valid[j, u] = 1.0
    return src_i, src_t, valid


@dataclasses.dataclass(frozen=True)
class FlatButterflyPattern:
    """Frozen description of one flat block butterfly weight pattern."""

    out_features: int
    in_features: int
    block: int
    max_stride: int
    cols: np.ndarray  # (nb_out, r) int32

    @property
    def nb_out(self) -> int:
        return self.out_features // self.block

    @property
    def nb_in(self) -> int:
        return self.in_features // self.block

    @property
    def r(self) -> int:
        return self.cols.shape[1]

    @property
    def nnz(self) -> int:
        return self.nb_out * self.r * self.block * self.block

    @property
    def density(self) -> float:
        return self.nnz / (self.out_features * self.in_features)

    def dense_mask(self) -> np.ndarray:
        return dense_mask_from_cols(self.nb_out, self.nb_in, self.cols, self.block)


def make_pattern(
    out_features: int,
    in_features: int,
    *,
    block: int = 128,
    max_stride: int | None = None,
    density: float | None = None,
) -> FlatButterflyPattern:
    """Build the static pattern for an ``(out, in)`` weight.

    Exactly one of ``max_stride`` / ``density`` may be given; with neither,
    the full flat butterfly (max stride = grid size) is used.
    """
    if out_features % block or in_features % block:
        raise ValueError(
            f"features ({out_features}, {in_features}) must be multiples of "
            f"block {block}"
        )
    nb_out, nb_in = out_features // block, in_features // block
    g = next_pow2(max(nb_out, nb_in))
    if max_stride is not None and density is not None:
        raise ValueError("give at most one of max_stride / density")
    if max_stride is None:
        if density is not None:
            max_stride = max_stride_for_density(in_features, block, density)
        else:
            max_stride = g
    max_stride = min(next_pow2(max_stride), g)
    cols = flat_butterfly_cols(nb_out, nb_in, max_stride)
    return FlatButterflyPattern(
        out_features=out_features,
        in_features=in_features,
        block=block,
        max_stride=max_stride,
        cols=cols,
    )
