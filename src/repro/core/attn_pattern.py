"""Pixelfly block-sparse *attention* patterns (paper §3.3, App. I.2/I.3).

The attention matrix is sparsified with the same recipe as weights:

- **local**: block-diagonal window (width ``local_blocks``) — the "Local"
  component of Fig. 12, block-aligned.
- **butterfly**: stride block diagonals ``j = i XOR s`` for
  ``s in {1,2,4,…,k/2}`` — the flat block butterfly pattern.
- **global**: first ``global_blocks`` block rows+columns. Per App. I.2 a
  width-w global cross has rank <= 2w, so this *is* the low-rank term of
  ``W = γB + (1-γ)UVᵀ`` in attention form (kept block-aligned).

All masks are boolean numpy arrays over *blocks*; they are fixed at model
construction (static sparsity) and drive the Pallas kernel's KV-block
schedule.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import butterfly

__all__ = [
    "AttentionPatternConfig",
    "pixelfly_attention_block_mask",
    "block_schedule",
    "BlockSchedule",
    "keys_per_query",
]


@dataclasses.dataclass(frozen=True)
class AttentionPatternConfig:
    block: int = 128            # hardware block (query & key granularity)
    local_blocks: int = 1       # width of the block-diagonal window
    max_stride: int = 0         # 0 -> full flat butterfly on the block grid
    global_blocks: int = 1      # width of the global cross (low-rank part)


def pixelfly_attention_block_mask(
    seq_q: int,
    seq_k: int,
    cfg: AttentionPatternConfig,
    *,
    causal: bool = False,
) -> np.ndarray:
    """Boolean (nqb, nkb) block mask: local + butterfly + global."""
    b = cfg.block
    nqb = -(-seq_q // b)
    nkb = -(-seq_k // b)
    g = butterfly.next_pow2(max(nqb, nkb))
    max_stride = cfg.max_stride or g
    max_stride = min(butterfly.next_pow2(max_stride), g)
    strides = butterfly.flat_butterfly_strides(max_stride)

    mask = np.zeros((nqb, nkb), dtype=bool)
    qi = np.arange(nqb)
    # local window (in stretched grid space so rectangular masks behave)
    for i in range(nqb):
        gi = i * g // nqb
        lo = max(0, (gi - (cfg.local_blocks - 1)) * nkb // g)
        hi = min(nkb, (gi + cfg.local_blocks) * nkb // g + 1)
        mask[i, lo:hi] = True
        for s in strides:
            j = (gi ^ s) * nkb // g
            if j < nkb:
                mask[i, j] = True
    if cfg.global_blocks > 0:
        mask[: cfg.global_blocks, :] = True
        mask[:, : cfg.global_blocks] = True
    if causal:
        # Drop blocks entirely above the causal diagonal (element-level
        # causality inside boundary blocks is the kernel's job).
        ji = np.arange(nkb)
        keep = ji[None, :] * b <= qi[:, None] * b + (b - 1)
        mask &= keep
    return mask


@dataclasses.dataclass(frozen=True)
class BlockSchedule:
    """Per-q-block KV visit list for the Pallas kernel (padded, static)."""

    kv_index: np.ndarray  # (nqb, max_nkv) int32, padded with 0
    valid: np.ndarray     # (nqb, max_nkv) int32 {0,1}
    block_q: int
    block_k: int

    @property
    def nqb(self) -> int:
        return self.kv_index.shape[0]

    @property
    def max_nkv(self) -> int:
        return self.kv_index.shape[1]


def block_schedule(
    block_mask: np.ndarray, block_q: int, block_k: int
) -> BlockSchedule:
    """Turn a boolean block mask into a padded per-row KV schedule."""
    nqb, nkb = block_mask.shape
    rows = [np.nonzero(block_mask[i])[0] for i in range(nqb)]
    width = max(1, max(len(r) for r in rows))
    kv = np.zeros((nqb, width), dtype=np.int32)
    valid = np.zeros((nqb, width), dtype=np.int32)
    for i, r in enumerate(rows):
        kv[i, : len(r)] = r
        valid[i, : len(r)] = 1
    return BlockSchedule(kv_index=kv, valid=valid, block_q=block_q, block_k=block_k)


def keys_per_query(block_mask: np.ndarray, block_k: int, seq_k: int) -> float:
    """Average number of attended keys per query — the O(n·b·log n) claim."""
    per_row_blocks = block_mask.sum(axis=1)
    return float(per_row_blocks.mean() * block_k)
