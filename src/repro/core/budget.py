"""Compute-budget allocation across layer types (paper §3.3 step 1, App. A, I.1).

The paper's cost model:  Totalcost = Cost_mem * N_blockmem + Cost_flop * N_flop
with block-aligned sparsity, so both terms scale linearly in density. The
rule of thumb (validated in App. I): allocate the sparsity compute budget to
each layer *type* proportional to that type's share of the dense compute,
then split each layer's budget ~1/4 low-rank : ~3/4 flat block butterfly
(§5.3 ablation).
"""

from __future__ import annotations

import dataclasses

from repro.core import butterfly

__all__ = [
    "LayerSchema",
    "dense_flops",
    "allocate",
    "Allocation",
    "split_sparse_lowrank",
    "solve_two_type_closed_form",
]


@dataclasses.dataclass(frozen=True)
class LayerSchema:
    """One row of the model schema Ω = {(type, repeats, m, n)} (App. K.2)."""

    kind: str  # e.g. "attn_proj", "mlp", "attention_matrix"
    repeats: int
    m: int  # out features (or seq len for attention matrices)
    n: int  # in features
    seq_len: int = 1  # tokens multiplying this GEMM (for compute weighting)

    def dense_flops_per_token(self) -> float:
        return 2.0 * self.repeats * self.m * self.n


def dense_flops(schema: list[LayerSchema]) -> float:
    return sum(s.dense_flops_per_token() * s.seq_len for s in schema)


@dataclasses.dataclass(frozen=True)
class Allocation:
    """Chosen density + its split for one layer type."""

    kind: str
    density: float
    lowrank_rank: int
    max_stride: int
    block: int


def split_sparse_lowrank(
    out_features: int,
    in_features: int,
    density: float,
    *,
    block: int = 128,
    lowrank_frac: float = 0.25,
) -> tuple[int, int]:
    """Split a layer's density budget into (rank, max_stride) (§3.3 step 2).

    ~``lowrank_frac`` of the parameter budget goes to the low-rank term
    UVᵀ; the rank is a multiple of 32 (the paper's "smallest supported
    block size" — on TPU the 8x128 VPU tile pads rank-32 factors without
    waste), minimum 32. The remainder picks the largest flat-butterfly max
    stride that fits.
    """
    total_params = density * out_features * in_features
    lr_params_per_rank = out_features + in_features
    gran = 32
    if lowrank_frac <= 0:
        # butterfly-only ablation (§5.3): no low-rank term at all
        return 0, butterfly.max_stride_for_density(
            in_features, block, max(density, block / in_features)
        )
    rank = int(lowrank_frac * total_params / lr_params_per_rank)
    rank = max(gran, (rank // gran) * gran)
    # never let the minimum-rank floor blow past ~1.5x the low-rank budget
    while rank > gran and rank * lr_params_per_rank > 1.5 * lowrank_frac * total_params:
        rank -= gran
    remaining = max(0.0, total_params - rank * lr_params_per_rank)
    sparse_density = remaining / (out_features * in_features)
    # At least the block diagonal survives.
    max_stride = butterfly.max_stride_for_density(
        in_features, block, max(sparse_density, block / in_features)
    )
    return rank, max_stride


def allocate(
    schema: list[LayerSchema],
    total_density: float,
    *,
    block: int = 128,
    lowrank_frac: float = 0.25,
) -> dict[str, Allocation]:
    """Rule-of-thumb allocation (§3.3 step 1).

    The total budget is ``total_density * dense_flops``. Each layer type
    receives budget proportional to its dense compute fraction — which for a
    linear cost model is the same as giving every type the *same density*
    ``total_density``; the interesting work is the per-layer split into
    low-rank + butterfly, which depends on each layer's (m, n).
    """
    out: dict[str, Allocation] = {}
    for s in schema:
        rank, max_stride = split_sparse_lowrank(
            s.m, s.n, total_density, block=block, lowrank_frac=lowrank_frac
        )
        out[s.kind] = Allocation(
            kind=s.kind,
            density=total_density,
            lowrank_rank=rank,
            max_stride=max_stride,
            block=block,
        )
    return out


def solve_two_type_closed_form(
    seq_len: int, d_model: int, param_budget: float
) -> tuple[float, float]:
    """Closed-form solution of the App. I.1 two-variable problem (Eq. 20).

    minimize  d_a (s^2 + s d) + 2 d_m s d   s.t.  params(d_a, d_m) <= B.

    Attention-density parameters scale with s*d per layer (projections) and
    the MLP with 8 d^2 (4x expansion, two matrices); the cost is linear in
    both densities, so the optimum lies on the budget boundary and the
    cheapest cost-per-parameter type is filled last. Returns (d_a, d_m),
    both clipped to [min_density, 1].
    """
    # Cost per unit density.
    cost_a = seq_len * seq_len + seq_len * d_model
    cost_m = 2 * seq_len * d_model
    # Parameters per unit density.
    par_a = 4 * d_model * d_model
    par_m = 8 * d_model * d_model
    # Cost-per-parameter; spend budget on the cheaper type first.
    eff_a, eff_m = cost_a / par_a, cost_m / par_m
    budget = param_budget
    d_a = d_m = 0.0
    order = sorted([("a", eff_a, par_a), ("m", eff_m, par_m)], key=lambda t: t[1])
    for kind, _, par in order:
        take = min(1.0, budget / par)
        if kind == "a":
            d_a = take
        else:
            d_m = take
        budget -= take * par
        if budget <= 0:
            break
    return d_a, d_m
