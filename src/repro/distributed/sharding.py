"""Sharding rules: parameter / batch / cache PartitionSpecs per mesh.

Two layouts (DESIGN.md §4), selected per architecture by the launcher:

- ``Strategy("tp")``: tensor/expert parallel over ``model`` on the natural
  tensor axis (heads / ffn / experts / vocab / butterfly block-rows), FSDP
  (ZeRO-3) over ``data`` (+``pod``) on a second axis, batch over
  (pod, data). For big models.
- ``Strategy("fsdp")``: no TP — all axes are data axes; batch shards over
  everything and parameters are FSDP-sharded where divisible. For small
  models, where TP-16 would be dominated by per-layer activation
  collectives (measured: smollm-360m on 16x16 spent 26ms/step on
  collectives under TP vs ~0 under FSDP).

All rules are divisibility-guarded; anything non-divisible falls back to
replication so every (arch x shape x mesh) cell lowers.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import MODEL_AXIS

__all__ = [
    "Strategy",
    "param_specs",
    "param_shardings",
    "batch_specs",
    "cache_specs",
    "named",
    "constrain_pools",
]


class Strategy:
    """How the mesh axes are used ("tp" vs "fsdp" — see module docstring)."""

    def __init__(self, mesh: Mesh, kind: str = "tp"):
        if kind not in ("tp", "fsdp"):
            raise ValueError(kind)
        self.kind = kind
        self.mesh = mesh
        names = mesh.axis_names
        if kind == "tp":
            self.model_axis: str | None = (
                MODEL_AXIS if MODEL_AXIS in names else None
            )
            self.fsdp: tuple[str, ...] = tuple(
                a for a in ("pod", "data") if a in names
            )
        else:
            self.model_axis = None
            self.fsdp = tuple(
                a for a in ("pod", "data", "model") if a in names
            )
        self.batch: tuple[str, ...] = self.fsdp

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.model_axis else 1


def _axsize(mesh: Mesh, axes) -> int:
    if not axes:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(mesh: Mesh, dim: int, axes) -> bool:
    return axes is not None and dim % _axsize(mesh, axes) == 0


def _maybe(st: Strategy, dim: int, axes):
    if not axes:
        return None
    return axes if _fits(st.mesh, dim, axes) else None


def _batch_axes_for(st: Strategy, dim: int):
    """Largest suffix of the batch axes that divides ``dim`` (None if only
    a trivial size-1 sharding remains)."""
    axes = st.batch
    while axes and dim % _axsize(st.mesh, axes) != 0:
        axes = axes[1:]
    if not axes or _axsize(st.mesh, axes) == 1:
        return None
    return axes


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _greedy(st: Strategy, dims: tuple[int, ...]) -> list:
    """Put model then fsdp on the largest divisible dims."""
    entries: list[Any] = [None] * len(dims)
    used: set[int] = set()
    for axes in (st.model_axis, st.fsdp or None):
        if axes is None:
            continue
        cands = [
            d
            for d in range(len(dims))
            if d not in used and _fits(st.mesh, dims[d], axes)
            and dims[d] >= _axsize(st.mesh, axes)
        ]
        if cands:
            d = max(cands, key=lambda i: dims[i])
            entries[d] = axes
            used.add(d)
    return entries


def _param_spec(st: Strategy, path: str, shape: tuple[int, ...]) -> P:
    mesh = st.mesh
    ma, fsdp = st.model_axis, st.fsdp or None
    # Scanned groups carry a leading layer dim; shared groups (zamba2's
    # shared attention block) are stored unstacked.
    stacked = path.startswith("groups/") and not path.startswith(
        "groups/shared_"
    )
    lead = 1 if stacked else 0
    dims = shape[lead:]

    def pad(*entries):
        return P(*([None] * lead), *entries)

    # ---- embeddings / lm head
    if re.search(r"embed/tok$", path):
        return P(_maybe(st, shape[0], ma), _maybe(st, shape[1], fsdp))
    if re.search(r"head/w$", path):
        return P(_maybe(st, shape[0], fsdp), _maybe(st, shape[1], ma))

    # ---- MoE experts (E, ...): expert-parallel over model
    if "/moe/" in path:
        if re.search(r"/router$", path):
            return pad(None, _maybe(st, dims[-1], ma))
        if re.search(r"/moe/w[gud]($|/)", path):
            ent = [None] * len(dims)
            ent[0] = _maybe(st, dims[0], ma)
            if ent[0] is None and fsdp:  # fsdp strategy: shard experts on fsdp
                ent[0] = _maybe(st, dims[0], fsdp)
                return pad(*ent)
            cands = [
                d for d in range(len(dims) - 1, 0, -1)
                if fsdp and _fits(mesh, dims[d], fsdp)
            ]
            if cands:
                d = max(cands, key=lambda i: dims[i])
                ent[d] = fsdp
            return pad(*ent)

    # ---- pixelfly sparse linears
    if re.search(r"/blocks$", path):  # (nb_out, r, b, b)
        nb, r, b1, b2 = dims
        if _fits(mesh, nb, ma):
            return pad(ma, None, None, _maybe(st, b2, fsdp))
        return pad(
            _maybe(st, nb, fsdp), None, None, _maybe(st, b2, ma)
        )
    if re.search(r"/U$", path):
        return pad(_maybe(st, dims[0], fsdp), None)
    if re.search(r"/V$", path):
        spec0 = _maybe(st, dims[0], ma) or _maybe(st, dims[0], fsdp)
        return pad(spec0, None)

    # ---- dense linears
    if re.search(r"/(wo|wd|out_proj)/w$", path):
        return pad(_maybe(st, dims[0], ma), _maybe(st, dims[1], fsdp))
    if re.search(r"/(wq|wk|wv|wg|wu|in_proj|w1|w2|qkv|proj)/w$", path):
        return pad(_maybe(st, dims[0], fsdp), _maybe(st, dims[1], ma))
    if re.search(r"/b$", path) and len(dims) == 1:
        return pad(_maybe(st, dims[0], ma))

    # ---- ssm internals
    if re.search(r"/conv_w$", path):
        return pad(None, _maybe(st, dims[1], ma))

    if len(dims) <= 1:
        return pad(*([None] * len(dims)))
    return pad(*_greedy(st, dims))


def param_specs(st: Strategy, params) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, a: _param_spec(st, _path_str(p), tuple(a.shape)), params
    )


def named(mesh_or_st, tree):
    mesh = mesh_or_st.mesh if isinstance(mesh_or_st, Strategy) else mesh_or_st
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def constrain_pools(pools, shardings):
    """Pin the paged-pool layout on an in-jit pool write (the PR 7
    invariant jaxlint enforces as JL005): without the constraint GSPMD
    is free to materialize the whole pool under a different layout
    around the ``.at[...].set`` and reshard it back.  ``shardings`` is
    the pool-shaped tree of ``NamedSharding`` from ``PagedKVCache`` (or
    None on a single-device engine, where this is a no-op).  Unlike the
    ``PartitionSpec``-based ``layers.constrain_paged_pool``, a
    ``NamedSharding`` carries its mesh, so callers need no ambient
    ``with mesh:`` context."""
    if shardings is None:
        return pools
    return jax.tree.map(
        lambda b, s: jax.lax.with_sharding_constraint(b, s), pools, shardings
    )


def param_shardings(st: Strategy, params):
    return named(st, param_specs(st, params))


def batch_specs(st: Strategy, batch) -> Any:
    """Shard every batch leaf's leading (batch) dim as much as divisible."""

    def spec(a):
        if a.ndim == 0:
            return P()
        return P(_batch_axes_for(st, a.shape[0]), *([None] * (a.ndim - 1)))

    return jax.tree.map(spec, batch)


def _paged_pool_spec(st: Strategy, shape: tuple[int, ...]) -> P:
    """Slot-shared page pools: ``(count, n_pages, page, kv_heads,
    head_dim)`` leaves (or the per-layer 4-dim view inside a group scan).
    The page axes are allocator-owned — any physical page may map into
    any slot's table, and the host rewrites the page table every step —
    so they must stay replicated; only the trailing *head* axes shard.
    kv_heads on the model axis is classic head-parallel attention;
    head_dim is the fallback for GQA head counts the mesh doesn't
    divide. Data axes replicate: data parallelism over serving traffic
    is replica routing at the engine layer (``serving.router``), not a
    sharded pool. Must agree with ``layers.paged_pool_entry`` — the
    in-jit constraint and the buffer sharding pin the same layout."""
    ent: list[Any] = [None] * len(shape)
    ma = st.model_axis
    if ma and len(shape) >= 2:
        for d in (len(shape) - 2, len(shape) - 1):
            if _fits(st.mesh, shape[d], ma) and shape[d] >= _axsize(
                st.mesh, ma
            ):
                ent[d] = ma
                break
    return P(*ent)


def cache_specs(st: Strategy, caches, *, layout: str = "decode") -> Any:
    """Decode caches: (count, B, ...) leaves. Batch over the data axes when
    divisible, model on the LAST divisible trailing dim (head_dim/state) —
    not the sequence dim, where a seq-sharded KV cache forces GSPMD to
    reshard around every dynamic_update_slice.

    ``layout="paged"`` switches to the serving engine's slot-shared page
    pools, whose leaves are (count, n_pages, page, kv_heads, head_dim)
    with no batch dim at all — see ``_paged_pool_spec``."""
    mesh = st.mesh
    if layout == "paged":
        return jax.tree.map(
            lambda a: _paged_pool_spec(st, tuple(a.shape)), caches
        )
    if layout != "decode":
        raise ValueError(f"unknown cache layout {layout!r}")

    def spec(a):
        if a.ndim <= 1:
            return P(*([None] * a.ndim))
        ent = [None] * a.ndim
        baxes = _batch_axes_for(st, a.shape[1])
        batch_sharded = bool(baxes) and a.shape[1] >= _axsize(mesh, baxes)
        if batch_sharded:
            ent[1] = baxes
        if st.model_axis:
            cands = [
                d
                for d in range(2, a.ndim)
                if _fits(mesh, a.shape[d], st.model_axis)
                and a.shape[d] >= _axsize(mesh, st.model_axis)
            ]
            if cands:
                ent[cands[-1]] = st.model_axis
        if not batch_sharded and st.batch:
            # batch=1 long-context decode: shard the longest remaining dim
            # (the 500k sequence axis) over the data axes instead of
            # replicating a multi-GB cache on every device.
            cands = [
                d
                for d in range(2, a.ndim)
                if ent[d] is None and _fits(mesh, a.shape[d], st.batch)
                and a.shape[d] >= _axsize(mesh, st.batch)
            ]
            if cands:
                d = max(cands, key=lambda i: a.shape[i])
                ent[d] = st.batch
        return P(*ent)

    return jax.tree.map(spec, caches)
