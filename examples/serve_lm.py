"""Continuous-batching serving example with per-request sampling (CPU).

Mixed-length requests flow through ``repro.serving.Engine``: jit'd
bucketed prefill into the block-paged KV cache, slot-based admission and
eviction per step, one jit'd decode step over all slots. Each request
carries its own ``SamplingParams`` — greedy, temperature, top-k/top-p —
sampled *inside* the jit'd step from the request's own seeded noise
stream, so the decoding mix costs the same host syncs as all-greedy.
Two late requests are submitted mid-flight to show slots refilling.

  PYTHONPATH=src python examples/serve_lm.py [--smoke]
"""

import argparse

import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.serving import Engine, EngineConfig, SamplingParams


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short requests (the tier-1 dry-run)")
    args = ap.parse_args(argv)

    cfg = registry.get_smoke("smollm-360m", sparse=True)
    if args.smoke:
        cfg = cfg.replace(num_layers=2, vocab_size=128)
    engine = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(max_slots=3, max_len=128),
    )
    rng = np.random.default_rng(0)
    # one greedy request, the rest sampled — each with its own seed
    samplers = [
        SamplingParams(),  # greedy
        SamplingParams(temperature=0.8, top_k=50, seed=1),
        SamplingParams(temperature=0.7, top_p=0.95, seed=2),
        SamplingParams(temperature=1.0, repetition_penalty=1.2, seed=3),
    ]
    for i, (plen, gen) in enumerate([(16, 12), (9, 6), (24, 10), (5, 8)]):
        engine.submit(
            rng.integers(0, cfg.vocab_size, plen), gen,
            sampling=samplers[i % len(samplers)],
        )
    finished = []
    for _ in range(6):  # first wave makes progress...
        finished += engine.step()
    for plen, gen, sp in [  # ...then late arrivals join
        (12, 5, SamplingParams(temperature=0.9, top_k=20, seed=4)),
        (7, 9, SamplingParams()),
    ]:
        engine.submit(rng.integers(0, cfg.vocab_size, plen), gen,
                      sampling=sp)
    finished += engine.drain()

    for f in sorted(finished, key=lambda f: f.uid):
        print(
            f"req {f.uid}: prompt {f.prompt.size:>2} tok -> "
            f"{len(f.tokens):>2} generated ({f.finish_reason}, "
            f"admitted step {f.admit_step}) {f.tokens[:8]}"
        )
    s = engine.stats_summary()
    print(
        f"\n{s['generated_tokens']} tokens, {s['tok_s']} tok/s, "
        f"occupancy mean {s['mean_occupancy']} "
        f"(min {s['min_occupancy']}, max {s['max_occupancy']})"
    )
    print("by sampler:", {
        k: v["requests"] for k, v in s["by_sampler"].items()
    })


if __name__ == "__main__":
    main()
