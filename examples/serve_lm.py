"""Batched serving example: prefill + decode with a KV cache on a smoke
config (CPU). The production path for the full configs is exercised by the
multi-pod dry-run (decode_32k / long_500k cells).

  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Server


def main():
    cfg = registry.get_smoke("smollm-360m", sparse=True)
    server = Server(cfg, make_local_mesh())
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(4, 16), dtype=np.int32
    )
    out = server.generate(prompts, gen_len=12)
    print("generated token grid (4 requests x 12 tokens):")
    print(out)


if __name__ == "__main__":
    main()
