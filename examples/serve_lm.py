"""Continuous-batching serving example on a smoke config (CPU).

Mixed-length requests flow through ``repro.serving.Engine``: jit'd
bucketed prefill into the block-paged KV cache, slot-based admission and
eviction per step, one jit'd decode step over all slots. Two late
requests are submitted mid-flight to show slots refilling.

  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.serving import Engine, EngineConfig


def main():
    cfg = registry.get_smoke("smollm-360m", sparse=True)
    engine = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(max_slots=3, max_len=128),
    )
    rng = np.random.default_rng(0)
    for plen, gen in [(16, 12), (9, 6), (24, 10), (5, 8)]:
        engine.submit(rng.integers(0, cfg.vocab_size, plen), gen)
    finished = []
    for _ in range(6):  # first wave makes progress...
        finished += engine.step()
    for plen, gen in [(12, 5), (7, 9)]:  # ...then late arrivals join
        engine.submit(rng.integers(0, cfg.vocab_size, plen), gen)
    finished += engine.drain()

    for f in sorted(finished, key=lambda f: f.uid):
        print(
            f"req {f.uid}: prompt {f.prompt.size:>2} tok -> "
            f"{len(f.tokens):>2} generated ({f.finish_reason}, "
            f"admitted step {f.admit_step}) {f.tokens[:8]}"
        )
    s = engine.stats_summary()
    print(
        f"\n{s['generated_tokens']} tokens, {s['tok_s']} tok/s, "
        f"occupancy mean {s['mean_occupancy']} "
        f"(min {s['min_occupancy']}, max {s['max_occupancy']})"
    )


if __name__ == "__main__":
    main()
