"""Ablation (paper §5.3): sweep density and the butterfly/low-rank split
on a small LM; prints loss and params per setting — the CPU twin of the
'1/4 low-rank : 3/4 butterfly is best' finding.

  PYTHONPATH=src python examples/sparsity_ablation.py [--steps 60]
"""

import argparse

import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.training.data import SyntheticLM
from repro.training.loop import TrainConfig, Trainer
from repro.training.optimizer import OptConfig
import jax


def run_one(density, lowrank_frac, steps):
    # widths where the budget split is non-degenerate (rank floor = 32)
    cfg = registry.get_smoke("smollm-360m", sparse=True).replace(
        sparse_density=density, lowrank_frac=lowrank_frac, num_layers=2,
        d_model=384, num_heads=6, num_kv_heads=2, d_ff=768, sparse_block=16,
    )
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    tr = Trainer(
        cfg,
        OptConfig(lr=3e-3, warmup_steps=5, total_steps=steps),
        data,
        make_local_mesh(),
        TrainConfig(steps=steps, ckpt_dir=f"/tmp/abl_{density}_{lowrank_frac}",
                    ckpt_every=10**9, log_every=10**9),
    )
    hist = tr.run()
    n = sum(p.size for p in jax.tree.leaves(tr.state["params"]))
    return float(np.mean([h["loss"] for h in hist[-5:]])), n


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--smoke", action="store_true",
                    help="one sweep cell, 2 steps (the tier-1 dry-run)")
    args = ap.parse_args(argv)
    grid = [(0.4, 0.25)] if args.smoke else [
        (d, f) for d in [0.2, 0.4, 0.8] for f in [0.0, 0.25, 0.5]
    ]
    steps = 2 if args.smoke else args.steps
    print("density,lowrank_frac,final_loss,params")
    for density, frac in grid:
        loss, n = run_one(density, frac, steps)
        print(f"{density},{frac},{loss:.4f},{n}")


if __name__ == "__main__":
    main()
