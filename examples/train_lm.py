"""End-to-end driver: train a ~100M-class LM (reduced here for CPU) with
Pixelfly sparsity, checkpointing, and resume — deliverable (b)'s
train-a-model-for-a-few-hundred-steps example.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import tempfile

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.training.data import SyntheticLM
from repro.training.loop import TrainConfig, Trainer
from repro.training.optimizer import OptConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model, 2 steps (the tier-1 dry-run)")
    args = ap.parse_args(argv)

    cfg = registry.get_smoke("qwen3-1.7b", sparse=not args.dense)
    if args.smoke:
        args.steps = 2
        args.ckpt_dir = tempfile.mkdtemp(prefix="repro_smoke_lm_")
        cfg = cfg.replace(num_layers=2, vocab_size=256)
        data = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    else:
        data = SyntheticLM(cfg.vocab_size, 128, 8, seed=0)
    trainer = Trainer(
        cfg,
        OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
        data,
        make_local_mesh(),
        TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=50, log_every=20),
    )
    hist = trainer.run()
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"in {trainer.step} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
