"""Quickstart: sparsify one linear layer with Pixelated Butterfly.

Shows the core API in ~40 lines: build the flat-block-butterfly + low-rank
spec from a density budget, initialize, apply, and inspect the savings.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.pixelfly import LinearSpec, apply_linear, init_linear, param_count

IN, OUT, DENSITY = 1024, 4096, 0.15

dense = LinearSpec.dense(IN, OUT, dtype=jnp.float32)
sparse = LinearSpec.pixelfly(IN, OUT, DENSITY, block=128, dtype=jnp.float32)

print(f"dense params : {param_count(dense):,}")
print(f"pixelfly     : {param_count(sparse):,} "
      f"({param_count(sparse)/param_count(dense):.1%} of dense)")
pat = sparse.pattern()
print(f"pattern      : block={pat.block} max_stride={pat.max_stride} "
      f"slots/row={pat.r} rank={sparse.rank}")

params = init_linear(jax.random.PRNGKey(0), sparse)
x = jax.random.normal(jax.random.PRNGKey(1), (8, IN), jnp.float32)
y = apply_linear(sparse, params, x)
print(f"y = x @ (gamma*B + (1-gamma)*UV^T): {x.shape} -> {y.shape}, "
      f"gamma={float(params['gamma']):.2f}")

# the mask is static & hardware-block-aligned — the whole point:
import numpy as np
from repro.core.butterfly import block_cover
m = pat.dense_mask()
assert np.array_equal(m, block_cover(m, pat.block, pat.block))
print("mask is its own block cover: every byte a block device touches is used")
