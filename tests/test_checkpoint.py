"""Checkpoint substrate: atomicity, integrity, retention, elasticity."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ck


def _tree(v=1.0):
    return {"a": jnp.full((4, 4), v), "b": {"c": jnp.arange(6).reshape(2, 3)}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    ck.save(d, 5, _tree(2.5), extra={"step": 5})
    out, extra = ck.restore(d, _tree(0.0))
    np.testing.assert_array_equal(np.asarray(out["a"]), 2.5)
    assert extra["step"] == 5


def test_retention(tmp_path):
    d = str(tmp_path)
    for s in [1, 2, 3, 4, 5]:
        ck.save(d, s, _tree(), keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and ck.latest_step(d) == 5


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    path = ck.save(d, 1, _tree())
    # rewrite the arrays file with silently-changed data (manifest CRCs stale)
    f = os.path.join(path, "arrays.npz")
    loaded = dict(np.load(f))
    loaded["a"] = loaded["a"] + 1.0
    with open(f, "wb") as fh:
        np.savez(fh, **loaded)
    with pytest.raises(ck.CheckpointError, match="CRC"):
        ck.restore(d, _tree())


def test_stale_tmp_cleaned(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_00000001.tmp"))
    ck.save(d, 2, _tree())
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros((2, 3), jnp.int32)}}
    with pytest.raises(ck.CheckpointError):
        ck.restore(d, bad)


def test_elastic_restore_on_new_sharding(tmp_path):
    """Checkpoint written on one 'mesh' restores under different shardings
    (here: simply new device placement — layout is logical)."""
    d = str(tmp_path)
    ck.save(d, 1, _tree(3.0))
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        _tree(),
    )
    out, _ = ck.restore(d, _tree(), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["a"]), 3.0)
