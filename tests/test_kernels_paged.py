"""Differential parity: Pallas paged-decode kernel vs the jnp paged oracle.

The kernel (`repro.kernels.paged_attention`, run in interpret mode on CPU)
must reproduce ``paged_decode_attention_jnp`` / ``paged_sparse_decode_
attention_jnp`` (impl=None gather paths) across GQA ratios, dtypes,
ragged per-slot positions, partially-filled last pages, partially
allocated page-table rows, and idle slots parked on the trash page.
The trash page is poisoned with huge values so any masking divergence
between the two paths is loud, not a rounding blip.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.models import layers as L

PAGE, PPS, D = 8, 4, 16  # page size, pages per slot, head dim
ATOL = {jnp.float32: 1e-5, jnp.bfloat16: 1e-2}


def _scenario(b, hk, d, *, seed=0, dtype=jnp.float32, trash_slot=True,
              partial_slot=True):
    """Random pools + page table with the parity suite's edge cases:
    ragged positions (partial last pages), a partially-allocated row,
    and an idle slot whose row is all trash page."""
    rng = np.random.default_rng(seed)
    n_pages = b * PPS + 1
    k = rng.standard_normal((n_pages, PAGE, hk, d))
    v = rng.standard_normal((n_pages, PAGE, hk, d))
    # poison the trash page: an unmasked read of page 0 shows up as a
    # huge output delta instead of hiding inside the tolerance
    k[0] = 1e4
    v[0] = -1e4
    perm = rng.permutation(np.arange(1, n_pages))
    table = np.zeros((b, PPS), np.int32)
    pos = np.zeros((b,), np.int32)
    nxt = 0
    for s in range(b):
        if partial_slot and s == b - 1 and b > 1:
            n_alloc = 1  # partially-allocated row, trash tail
        else:
            n_alloc = PPS
        table[s, :n_alloc] = perm[nxt:nxt + n_alloc]
        nxt += n_alloc
        # ragged: land mid-page so the last page is partially filled
        pos[s] = int(rng.integers(0, n_alloc * PAGE))
    if trash_slot and b > 2:
        table[1] = 0  # idle slot: all-trash row, position 0
        pos[1] = 0
    return (
        jnp.asarray(k, dtype),
        jnp.asarray(v, dtype),
        jnp.asarray(table),
        jnp.asarray(pos),
        rng,
    )


def _q(rng, b, hk, g, d, dtype):
    return jnp.asarray(rng.standard_normal((b, 1, hk, g, d)), dtype)


def _run(fn, dtype):
    """Execute both impls, skipping when the CPU backend can't run the
    interpreted kernel's dtype (same idiom as the bsr attention tests)."""
    ref = fn(None)
    try:
        got = fn("interpret")
        got.block_until_ready()
    except Exception as e:  # pragma: no cover - backend-dependent
        if "Unsupported element type" in str(e):
            pytest.skip("CPU backend cannot execute this dtype")
        raise
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=ATOL[dtype],
        rtol=ATOL[dtype],
    )


@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_paged_parity(g, dtype):
    b, hk = 4, 2
    k, v, table, pos, rng = _scenario(b, hk, D, dtype=dtype)
    q = _q(rng, b, hk, g, D, dtype)
    _run(
        lambda impl: L.paged_decode_attention_jnp(
            q, k, v, table, pos, sm_scale=D ** -0.5, impl=impl
        ),
        dtype,
    )


@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_paged_parity(g, dtype):
    b, hk = 4, 2
    k, v, table, pos, rng = _scenario(b, hk, D, dtype=dtype)
    q = _q(rng, b, hk, g, D, dtype)
    _run(
        lambda impl: L.paged_sparse_decode_attention_jnp(
            q, k, v, table, pos, sm_scale=D ** -0.5,
            local_blocks=2, global_blocks=1, impl=impl,
        ),
        dtype,
    )


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_sparse_parity_random_schedules(seed):
    """Fuzz the schedule geometry: random local/global widths and ragged
    positions must stay in lockstep between kernel and oracle."""
    rng = np.random.default_rng(seed)
    b, hk, g = int(rng.integers(2, 5)), 2, int(rng.integers(1, 3))
    local = int(rng.integers(1, 3))
    glob = int(rng.integers(0, 2))
    k, v, table, pos, rng2 = _scenario(b, hk, D, seed=seed + 10)
    q = _q(rng2, b, hk, g, D, jnp.float32)
    _run(
        lambda impl: L.paged_sparse_decode_attention_jnp(
            q, k, v, table, pos, sm_scale=D ** -0.5,
            local_blocks=local, global_blocks=glob, impl=impl,
        ),
        jnp.float32,
    )


def test_dense_kernel_matches_contiguous_cache_oracle():
    """Materializing each slot's pages into a contiguous cache and running
    plain decode attention is the ground truth; the paged kernel must
    match it — not just the paged gather path — on fully-backed slots."""
    b, hk, g = 2, 2, 2
    k, v, table, pos, rng = _scenario(
        b, hk, D, trash_slot=False, partial_slot=False
    )
    q = _q(rng, b, hk, g, D, jnp.float32)
    got = L.paged_decode_attention_jnp(
        q, k, v, table, pos, sm_scale=D ** -0.5, impl="interpret"
    )
    tbl = np.asarray(table)
    for s in range(b):
        kc = jnp.asarray(np.asarray(k)[tbl[s]].reshape(1, PPS * PAGE, hk, D))
        vc = jnp.asarray(np.asarray(v)[tbl[s]].reshape(1, PPS * PAGE, hk, D))
        want = L.decode_attention_jnp(
            q[s:s + 1], kc, vc, pos[s], sm_scale=D ** -0.5
        )
        np.testing.assert_allclose(
            np.asarray(got[s:s + 1]), np.asarray(want), atol=1e-5, rtol=1e-5
        )


def test_sparse_kernel_covering_schedule_equals_dense():
    """With few enough pages the butterfly/local/global schedule covers
    every causal block, so the sparse kernel must equal the dense paged
    reference exactly (modulo fp tolerance)."""
    rng = np.random.default_rng(5)
    b, hk, g, pps = 3, 2, 2, 2
    n_pages = b * pps + 1
    k = rng.standard_normal((n_pages, PAGE, hk, D))
    v = rng.standard_normal((n_pages, PAGE, hk, D))
    k[0], v[0] = 1e4, -1e4
    perm = rng.permutation(np.arange(1, n_pages))
    table = jnp.asarray(perm.reshape(b, pps).astype(np.int32))
    pos = jnp.asarray(rng.integers(0, pps * PAGE, b).astype(np.int32))
    q = _q(rng, b, hk, g, D, jnp.float32)
    k, v = jnp.asarray(k, jnp.float32), jnp.asarray(v, jnp.float32)
    got = L.paged_sparse_decode_attention_jnp(
        q, k, v, table, pos, sm_scale=D ** -0.5,
        local_blocks=2, global_blocks=1, impl="interpret",
    )
    want = L.paged_decode_attention_jnp(
        q, k, v, table, pos, sm_scale=D ** -0.5
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5
    )


def test_trash_page_slot_is_benign():
    """A slot whose whole row is the trash page (idle slot, position 0)
    must stay finite and identical across impls — the serving engine
    parks evicted slots exactly like this."""
    b, hk, g = 4, 2, 1
    k, v, table, pos, rng = _scenario(b, hk, D)  # slot 1 is all-trash
    q = _q(rng, b, hk, g, D, jnp.float32)
    got = L.paged_sparse_decode_attention_jnp(
        q, k, v, table, pos, sm_scale=D ** -0.5,
        local_blocks=2, global_blocks=1, impl="interpret",
    )
    ref = L.paged_sparse_decode_attention_jnp(
        q, k, v, table, pos, sm_scale=D ** -0.5,
        local_blocks=2, global_blocks=1,
    )
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), atol=1e-5, rtol=1e-5
    )


def test_paged_sparse_schedule_properties():
    """The shared schedule helper: logical ids causal (never beyond the
    slot's current block), physical ids come from the page table, and the
    keep mask marks exactly the first occurrence of each logical block."""
    rng = np.random.default_rng(9)
    b, pps, page = 5, 8, 4
    table = jnp.asarray(
        rng.integers(1, 40, size=(b, pps)).astype(np.int32)
    )
    pos = jnp.asarray(rng.integers(0, pps * page, b).astype(np.int32))
    idx, phys, keep = L.paged_sparse_schedule(
        table, pos, page, local_blocks=2, global_blocks=1
    )
    idx, phys, keep = map(np.asarray, (idx, phys, keep))
    cur = np.asarray(pos) // page
    tbl = np.asarray(table)
    for s in range(b):
        assert (idx[s] <= cur[s]).all() and (idx[s] >= 0).all()
        assert (phys[s] == tbl[s][idx[s]]).all()
        seen = set()
        for t in range(idx.shape[1]):
            assert bool(keep[s, t]) == (idx[s, t] not in seen)
            seen.add(idx[s, t])
