"""HLO walker: exact FLOP accounting incl. while-loop trip counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_trip_count_multiplied():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    txt = _hlo(f, jnp.ones((128, 128), jnp.float32))
    c = roofline.analyze_hlo(txt)
    assert c.flops == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_plain_matmul_flops():
    txt = _hlo(lambda a, b: a @ b,
               jnp.ones((64, 32), jnp.float32), jnp.ones((32, 16), jnp.float32))
    c = roofline.analyze_hlo(txt)
    assert c.flops == pytest.approx(2 * 64 * 32 * 16, rel=0.01)


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bij,bjk->bik", a, b)
    txt = _hlo(f, jnp.ones((4, 8, 16), jnp.float32), jnp.ones((4, 16, 8), jnp.float32))
    c = roofline.analyze_hlo(txt)
    assert c.flops == pytest.approx(2 * 4 * 8 * 16 * 8, rel=0.01)


def test_nested_scan_multiplies():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    txt = _hlo(f, jnp.ones((64, 64), jnp.float32))
    c = roofline.analyze_hlo(txt)
    assert c.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.02)


def test_bytes_positive_and_sane():
    txt = _hlo(lambda a: (a @ a).sum(), jnp.ones((256, 256), jnp.float32))
    c = roofline.analyze_hlo(txt)
    assert c.bytes_accessed >= 2 * 256 * 256 * 4  # at least read a twice


def test_terms_and_bottleneck():
    cost = roofline.HloCost(
        flops=197e12, bytes_accessed=819e9 / 2, collective_bytes={}, n_collectives=0
    )
    t = roofline.roofline_terms(cost)
    assert t["bottleneck"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)


def test_model_flops_moe_uses_active():
    from repro.configs import registry
    dense = registry.get("deepseek-67b")
    moe = registry.get("kimi-k2-1t-a32b")
    # kimi total params >> deepseek, but ACTIVE flops should be same order
    f_moe = roofline.model_flops(moe, 1000)
    f_dense = roofline.model_flops(dense, 1000)
    assert f_moe < 2 * f_dense  # ~32B active vs 67B dense


def test_shape_parse():
    b, e = roofline._shape_info("bf16[256,128]{1,0}")
    assert e == 256 * 128 and b == 2 * e
    b, e = roofline._shape_info("(s32[], f32[4,4]{1,0})")
    assert e == 1 + 16 and b == 4 + 64
