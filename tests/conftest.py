import os
import sys

# Tests run on the single real CPU device (the dry-run sets its own flags
# in its own process). Keep any preexisting flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
