"""jaxlint rule fixtures (true positive / true negative / suppression
per rule) plus the self-scan: src/repro must be clean modulo the
committed baseline, and the baseline must carry no stale entries."""

from pathlib import Path

import pytest

from repro.analysis import jaxlint

ROOT = Path(__file__).resolve().parents[1]


def codes(src: str, path: str = "x.py") -> list[str]:
    return [f.code for f in jaxlint.lint_source(src, path)]


# ----------------------------------------------------------------------
# JL001 — implicit host sync in @hot_path functions
# ----------------------------------------------------------------------

HOT_HEADER = """
import jax
import jax.numpy as jnp
import numpy as np
from repro.analysis.guards import hot_path
"""


def test_jl001_item_true_positive():
    src = HOT_HEADER + """
@hot_path
def step(xs):
    y = jnp.sum(xs)
    return y.item()
"""
    assert codes(src) == ["JL001"]


def test_jl001_int_cast_on_device_value():
    src = HOT_HEADER + """
@hot_path
def step(xs):
    return int(jnp.argmax(xs))
"""
    assert codes(src) == ["JL001"]


def test_jl001_branch_on_device_value():
    src = HOT_HEADER + """
@hot_path
def step(xs):
    y = jnp.any(xs)
    if y:
        return 1
    return 0
"""
    assert codes(src) == ["JL001"]


def test_jl001_np_asarray_and_mapped_asarray():
    src = HOT_HEADER + """
@hot_path
def step(tree, xs):
    host = np.asarray(jnp.exp(xs))
    return jax.tree.map(np.asarray, tree), host
"""
    assert codes(src) == ["JL001", "JL001"]


def test_jl001_device_get_flagged_but_suppressible():
    src = HOT_HEADER + """
@hot_path
def step(toks_dev):
    return jax.device_get(toks_dev)
"""
    assert codes(src) == ["JL001"]
    sup = src.replace(
        "return jax.device_get(toks_dev)",
        "return jax.device_get(toks_dev)  "
        "# jaxlint: disable=JL001 -- the one batched per-step fetch",
    )
    assert codes(sup) == []


def test_jl001_jit_attr_results_are_tainted():
    # the Engine.step shape: self._decode is assigned from jax.jit in
    # __init__, so its call results are device values anywhere in the
    # class
    src = HOT_HEADER + """
class Engine:
    def __init__(self):
        self._decode = jax.jit(lambda x: x * 2)

    @hot_path
    def step(self, tokens):
        toks_dev = self._decode(jnp.asarray(tokens))
        return int(toks_dev[0])
"""
    assert codes(src) == ["JL001"]


def test_jl001_true_negatives():
    src = HOT_HEADER + """
@hot_path
def step(xs, reqs):
    tokens = np.zeros((4,), np.int32)      # host alloc: fine
    if reqs:                               # host container truthiness
        tokens[0] = len(reqs)
    nxt = jax.device_get(jnp.tanh(xs))  # jaxlint: disable=JL001 -- sanctioned
    return int(nxt[0])                     # int() on numpy: fine

def not_hot(xs):
    return jnp.sum(xs).item()              # not a hot path: fine
"""
    assert codes(src) == []


def test_jl000_reasonless_suppression_suppresses_nothing():
    src = HOT_HEADER + """
@hot_path
def step(xs):
    return jnp.sum(xs).item()  # jaxlint: disable=JL001
"""
    got = codes(src)
    assert "JL000" in got and "JL001" in got


# ----------------------------------------------------------------------
# JL002 — Python control flow over tracers inside jit
# ----------------------------------------------------------------------


def test_jl002_branch_on_tracer():
    src = """
import jax

@jax.jit
def f(x: jax.Array):
    if x > 0:
        return x
    return -x
"""
    assert codes(src) == ["JL002"]


def test_jl002_iteration_over_tracer():
    src = """
import jax

@jax.jit
def f(x: jax.Array):
    acc = 0
    for v in x:
        acc = acc + v
    return acc
"""
    assert codes(src) == ["JL002"]


def test_jl002_true_negatives_and_suppression():
    src = """
import jax

@jax.jit
def f(x: jax.Array, mode=None):
    if mode is None:              # is-None dispatch: static
        mode = "std"
    for i in range(x.shape[0]):   # shape is static under trace
        x = x + i
    while x.sum() > 0:  # jaxlint: disable=JL002 -- fixture: honored
        x = x - 1
    return x
"""
    assert codes(src) == []


# ----------------------------------------------------------------------
# JL003 — recompile hazards
# ----------------------------------------------------------------------


def test_jl003_jit_constructed_per_call():
    src = """
import jax

def g(x):
    f = jax.jit(lambda y: y * 2)
    return f(x)
"""
    assert codes(src) == ["JL003"]


def test_jl003_immediately_invoked_jit():
    src = """
import jax

def apply(fn, x):
    return jax.jit(fn)(x)
"""
    # constructed-in-function + immediately-invoked: both fire
    assert codes(src) == ["JL003", "JL003"]


def test_jl003_shape_closure_lambda():
    src = """
import jax

def make(x):
    n = x.shape[0]
    return jax.jit(lambda y: y.reshape(n))
"""
    got = codes(src)
    assert "JL003" in got
    msgs = [f.message for f in jaxlint.lint_source(src)]
    assert any("closes over" in m for m in msgs)


def test_jl003_container_literal_at_jit_callsite():
    src = """
import jax

@jax.jit
def f(x, cfg):
    return x

def caller(x):
    return f(x, {"mode": "fast", "k": 4})
"""
    assert codes(src) == ["JL003"]


def test_jl003_init_constructed_jits_are_fine():
    src = """
import jax

class Engine:
    def __init__(self):
        self._decode = jax.jit(lambda x: x)

    def run(self, x):
        return self._decode(x)
"""
    assert codes(src) == []


# ----------------------------------------------------------------------
# JL004 — Pallas structural checks
# ----------------------------------------------------------------------

PALLAS_HEADER = """
import functools
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
"""


def _pallas_fixture(in_map: str, out_map: str, operands: str,
                    kernel: str) -> str:
    return PALLAS_HEADER + f"""
{kernel}

def build(x, sched):
    grid = (4, 2)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, 1), {in_map})],
            out_specs=pl.BlockSpec((1, 1), {out_map}),
        ),
        out_shape=None,
    )({operands})
"""


GOOD_KERNEL = """
def _kernel(s_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]
"""


def test_jl004_index_map_arity():
    # grid rank 2 + 1 scalar-prefetch operand = 3 expected args
    src = _pallas_fixture(
        "lambda i, j: (i, j)",          # missing the prefetch ref
        "lambda i, j, s: (i, j)",
        "sched, x",
        GOOD_KERNEL,
    )
    found = [f for f in jaxlint.lint_source(src, "kernels/k.py")]
    assert [f.code for f in found] == ["JL004"]
    assert "expected 3" in found[0].message


def test_jl004_unmasked_validity_ref():
    bad_kernel = """
def _kernel(valid_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]
"""
    src = _pallas_fixture(
        "lambda i, j, s: (i, j)",
        "lambda i, j, s: (i, j)",
        "sched, x",
        bad_kernel,
    )
    found = jaxlint.lint_source(src, "kernels/k.py")
    assert [f.code for f in found] == ["JL004"]
    assert "valid_ref" in found[0].message


def test_jl004_masked_kernel_is_clean():
    masked_kernel = """
def _kernel(valid_ref, x_ref, o_ref):
    @pl.when(valid_ref[0] == 1)
    def _():
        o_ref[...] = x_ref[...]
"""
    src = _pallas_fixture(
        "lambda i, j, s: (i, j)",
        "lambda i, j, s: (i, j)",
        "sched, x",
        masked_kernel,
    )
    assert [f.code for f in jaxlint.lint_source(src, "kernels/k.py")] == []


def test_jl004_operand_count():
    # 1 prefetch + 1 in_spec = 2 operands; passing 3 means the prefetch
    # schedule slipped out of first position (or an operand is missing a
    # spec)
    src = _pallas_fixture(
        "lambda i, j, s: (i, j)",
        "lambda i, j, s: (i, j)",
        "sched, x, x",
        GOOD_KERNEL,
    )
    found = jaxlint.lint_source(src, "kernels/k.py")
    assert [f.code for f in found] == ["JL004"]
    assert "prefetch" in found[0].message


def test_jl004_index_maps_are_exempt_from_masking():
    # index maps receive the same prefetch refs but only compute block
    # coordinates — the real kernels' q_map/kv_map must not be flagged
    src = PALLAS_HEADER + """
def _kernel(pos_ref, x_ref, o_ref):
    o_ref[...] = jnp.where(pos_ref[0] >= 0, x_ref[...], 0.0)

def build(x, pos):
    grid = (4,)

    def pos_map(i, pos_ref):
        return (pos_ref[i],)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1,), pos_map)],
            out_specs=pl.BlockSpec((1,), pos_map),
        ),
        out_shape=None,
    )(pos, x)
"""
    assert [f.code for f in jaxlint.lint_source(src, "kernels/k.py")] == []


# ----------------------------------------------------------------------
# JL005 — unconstrained paged-pool writes
# ----------------------------------------------------------------------


def test_jl005_tree_mapped_pool_write():
    src = """
import jax

def scatter(buffers, idx, data):
    return jax.tree.map(lambda b, d: b.at[:, idx].set(d), buffers, data)
"""
    assert codes(src) == ["JL005"]


def test_jl005_direct_pool_write():
    src = """
def write(cache, phys, off, k):
    kc = cache["k"].at[phys, off].set(k)
    return kc
"""
    assert codes(src) == ["JL005"]


def test_jl005_constrained_write_is_clean():
    src = """
import jax
from repro.distributed.sharding import constrain_pools

def scatter(buffers, idx, data, shardings):
    out = jax.tree.map(lambda b, d: b.at[:, idx].set(d), buffers, data)
    return constrain_pools(out, shardings)
"""
    assert codes(src) == []


def test_jl005_non_pool_writes_are_fine():
    src = """
import jax.numpy as jnp

def route(x, gi, se, posc, xs):
    buf = jnp.zeros((4, 2, 8))
    buf = buf.at[gi, se, posc].add(xs)   # expert-capacity buffer
    return buf
"""
    assert codes(src) == []


def test_jl005_suppression_honored():
    src = """
import jax

def scatter(buffers, idx, data):
    # jaxlint: disable=JL005 -- fixture: single-device tool, no mesh
    return jax.tree.map(lambda b, d: b.at[:, idx].set(d), buffers, data)
"""
    assert codes(src) == []


# ----------------------------------------------------------------------
# JL006 — obs recorder calls inside jit-decorated functions
# ----------------------------------------------------------------------


def test_jl006_tracer_call_in_jit():
    src = """
import jax

@jax.jit
def decode(tracer, toks):
    tracer.begin(0, 1)
    out = toks * 2
    tracer.end(0, 1)
    return out
"""
    assert codes(src) == ["JL006", "JL006"]


def test_jl006_stats_record_and_metrics_inc_in_jit():
    src = """
import jax
import functools

@functools.partial(jax.jit, static_argnums=0)
def step(n, stats, metrics, xs):
    stats.record_host_sync()
    metrics.inc(n)
    return xs + n
"""
    assert codes(src) == ["JL006", "JL006"]


def test_jl006_true_negatives():
    # recorder calls outside jit are the sanctioned pattern, and
    # non-obs bases (``x.set`` on arrays, ``seen.end``) don't match
    src = """
import jax
import jax.numpy as jnp

@jax.jit
def step(xs, i):
    return xs.at[i].set(0)

def host_step(engine, xs):
    engine.tracer.begin(0, 1)
    out = step(xs, 0)
    engine.stats.record_host_sync()
    engine.tracer.end(0, 1)
    return out
"""
    assert codes(src) == []


def test_jl006_suppression_honored():
    src = """
import jax

@jax.jit
def debug_step(tracer, xs):
    # jaxlint: disable=JL006 -- fixture: trace-time marker, documented
    tracer.instant(0, 1)
    return xs
"""
    assert codes(src) == []


# ----------------------------------------------------------------------
# fingerprints, baseline, CLI
# ----------------------------------------------------------------------


def test_fingerprint_is_line_number_independent():
    src = """
import jax

def g(x):
    f = jax.jit(lambda y: y * 2)
    return f(x)
"""
    shifted = "\n\n\n" + src
    fp = jaxlint.lint_source(src)[0].fingerprint
    fp2 = jaxlint.lint_source(shifted)[0].fingerprint
    assert fp == fp2


def test_baseline_requires_reasons(tmp_path):
    bad = tmp_path / "b.txt"
    bad.write_text("some/file.py:JL003:g:f = jax.jit(\n")
    with pytest.raises(ValueError, match="reason"):
        jaxlint.load_baseline(bad)


def test_cli_reports_and_baselines(tmp_path, monkeypatch, capsys):
    mod = tmp_path / "m.py"
    mod.write_text(
        "import jax\n\ndef g(x):\n    f = jax.jit(lambda y: y)\n"
        "    return f(x)\n"
    )
    monkeypatch.chdir(tmp_path)
    assert jaxlint.main(["m.py"]) == 1
    out = capsys.readouterr().out
    assert "JL003" in out and "hint:" in out

    base = tmp_path / "base.txt"
    fp = jaxlint.lint_paths(["m.py"])[0].fingerprint
    base.write_text(f"{fp} # fixture: accepted\n")
    assert jaxlint.main(["m.py", "--baseline", "base.txt"]) == 0

    # fixing the finding strands the entry -> stale -> non-zero
    mod.write_text("def g(x):\n    return x\n")
    assert jaxlint.main(["m.py", "--baseline", "base.txt"]) == 1
    assert "stale" in capsys.readouterr().err


def test_self_scan_src_clean_modulo_baseline(monkeypatch):
    monkeypatch.chdir(ROOT)
    findings = jaxlint.lint_paths(["src"])
    baseline = jaxlint.load_baseline(ROOT / "jaxlint_baseline.txt")
    fresh = [f for f in findings if f.fingerprint not in baseline]
    assert not fresh, "unbaselined jaxlint findings:\n" + "\n".join(
        f.render() for f in fresh
    )
    stale = set(baseline) - {f.fingerprint for f in findings}
    assert not stale, f"stale baseline entries: {sorted(stale)}"
