"""ViT / MLP-Mixer (the paper's own base models) with pixelfly linears."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import vision as V


def _cfg(kind, sparse):
    return V.VisionConfig(
        kind=kind, num_layers=2, d_model=128, num_heads=4, d_ff=256,
        num_patches=64, num_classes=10, patch_dim=48, token_ff=64,
        sparse=sparse, sparse_density=0.4, sparse_block=32,
    )


@pytest.mark.parametrize("sparse", [False, True])
def test_vit(sparse):
    cfg = _cfg("vit", sparse)
    params = V.init_vit(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 64, 48)), jnp.float32)
    logits = V.apply_vit(cfg, params, x)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("sparse", [False, True])
def test_mixer(sparse):
    cfg = _cfg("mixer", sparse)
    params = V.init_mixer(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 64, 48)), jnp.float32)
    logits = V.apply_mixer(cfg, params, x)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_sparse_has_fewer_params():
    pd = V.init_mixer(jax.random.PRNGKey(0), _cfg("mixer", False))
    ps = V.init_mixer(jax.random.PRNGKey(0), _cfg("mixer", True))
    n = lambda t: sum(x.size for x in jax.tree.leaves(t))
    assert n(ps) < n(pd)


@pytest.mark.slow
def test_vit_trains():
    cfg = _cfg("vit", True)
    params = V.init_vit(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64, 48)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)

    def loss_fn(p):
        lg = V.apply_vit(cfg, p, x)
        return -jnp.take_along_axis(
            jax.nn.log_softmax(lg), y[:, None], axis=1
        ).mean()

    l0 = float(loss_fn(params))
    g = jax.grad(loss_fn)(params)
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1 = float(loss_fn(params2))
    assert l1 < l0
