"""Property tests: PagedKVCache allocator invariants.

Random alloc/free traces against a pure-python model of the free list.
The invariants the serving engine depends on every step: pages are never
leaked or double-allocated, the trash page (physical page 0) is never
handed out, freeing a slot restores ``free_pages`` and zeroes its
``page_table`` row.

A seeded numpy fuzz always runs (so the invariants gate every PR even
without dev deps); when ``hypothesis`` is installed the same traces are
additionally explored generatively with shrinking.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev dep optional
    HAVE_HYPOTHESIS = False

from repro.configs import registry
from repro.serving import PagedKVCache

SLOTS, PAGES_PER_SLOT, PAGE = 3, 4, 4
MAX_LEN = PAGES_PER_SLOT * PAGE


def _tiny_cfg():
    # shrink every model dim; only the cache geometry matters here
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=1, num_heads=2, num_kv_heads=1, head_dim=8,
        attn_block=PAGE,
    )


def _check_invariants(kv: PagedKVCache) -> None:
    owned = [p for pages in kv._owned.values() for p in pages]
    # no double allocation, no trash-page ownership
    assert len(owned) == len(set(owned))
    assert 0 not in owned and 0 not in kv._free
    # conservation: every non-trash page is exactly owned or free
    assert sorted(owned + kv._free) == list(range(1, kv.n_pages))
    assert kv.free_pages == kv.n_pages - 1 - len(owned)
    # page_table rows mirror the owned lists, trash-padded
    for slot in range(kv.max_slots):
        pages = kv._owned.get(slot, [])
        assert list(kv.page_table[slot, : len(pages)]) == pages
        assert (kv.page_table[slot, len(pages):] == 0).all()


def _run_trace(ops) -> None:
    kv = PagedKVCache(_tiny_cfg(), max_slots=SLOTS, max_len=MAX_LEN)
    assert kv.n_pages == SLOTS * PAGES_PER_SLOT + 1
    for op, slot, pos in ops:
        if op == "alloc":
            before = len(kv._owned.get(slot, []))
            kv.alloc_upto(slot, pos)
            # monotone: never shrinks, backs exactly pos // page + 1
            assert len(kv._owned[slot]) == max(before, pos // PAGE + 1)
        else:
            kv.free_slot(slot)
            assert slot not in kv._owned
            assert (kv.page_table[slot] == 0).all()
        _check_invariants(kv)
    for slot in range(SLOTS):
        kv.free_slot(slot)
    # full teardown restores every page
    assert kv.free_pages == kv.n_pages - 1
    assert (kv.page_table == 0).all()


def _roundtrip(positions, slot) -> None:
    kv = PagedKVCache(_tiny_cfg(), max_slots=SLOTS, max_len=MAX_LEN)
    total = kv.free_pages
    for pos in positions:
        kv.alloc_upto(slot, pos)
    want = max(p // PAGE + 1 for p in positions)
    assert kv.free_pages == total - want
    assert (kv.page_table[slot, :want] > 0).all()
    kv.free_slot(slot)
    assert kv.free_pages == total
    assert (kv.page_table[slot] == 0).all()
    _check_invariants(kv)


@pytest.mark.parametrize("seed", range(8))
def test_alloc_free_trace_never_leaks_seeded(seed):
    rng = np.random.default_rng(seed)
    ops = [
        (
            "alloc" if rng.random() < 0.7 else "free",
            int(rng.integers(0, SLOTS)),
            int(rng.integers(0, MAX_LEN)),
        )
        for _ in range(int(rng.integers(5, 40)))
    ]
    _run_trace(ops)


@pytest.mark.parametrize("seed", range(4))
def test_alloc_free_roundtrip_seeded(seed):
    rng = np.random.default_rng(100 + seed)
    positions = [int(rng.integers(0, MAX_LEN)) for _ in range(int(rng.integers(1, 8)))]
    _roundtrip(positions, int(rng.integers(0, SLOTS)))


def test_capacity_and_exhaustion_errors():
    kv = PagedKVCache(_tiny_cfg(), max_slots=SLOTS, max_len=MAX_LEN)
    with pytest.raises(ValueError):
        kv.alloc_upto(0, MAX_LEN)  # beyond per-slot capacity
    # freeing an unallocated slot is a no-op, not an error
    kv.free_slot(1)
    _check_invariants(kv)
    # drain the pool: allocation must fail loudly, not hand out trash
    for slot in range(SLOTS):
        kv.alloc_upto(slot, MAX_LEN - 1)
    assert kv.free_pages == 0
    kv.free_slot(0)
    kv._free.clear()  # simulate exhaustion with slot 0 unbacked
    with pytest.raises(RuntimeError):
        kv.alloc_upto(0, 0)
    assert 0 not in [p for ps in kv._owned.values() for p in ps]


if HAVE_HYPOTHESIS:

    @given(
        ops=st.lists(
            st.tuples(
                st.sampled_from(["alloc", "free"]),
                st.integers(0, SLOTS - 1),
                st.integers(0, MAX_LEN - 1),
            ),
            max_size=40,
        )
    )
    @settings(max_examples=25, deadline=None)
    def test_alloc_free_trace_never_leaks(ops):
        _run_trace(ops)

    @given(
        positions=st.lists(
            st.integers(0, MAX_LEN - 1), min_size=1, max_size=8
        ),
        slot=st.integers(0, SLOTS - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_alloc_free_roundtrip_restores_free_pages(positions, slot):
        _roundtrip(positions, slot)
