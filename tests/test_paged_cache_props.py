"""Property tests: PagedKVCache allocator invariants.

Random alloc/free traces — plus refcounted share / copy-on-write /
park / evict traces — against a pure-python model of the free list and
the prefix cache's page index. The invariants the serving engine
depends on every step: refcounts never go negative and always equal the
number of slots mapping a page (a refcount-1 page is owned by exactly
one slot), free ∪ owned ∪ cached is exactly the pool, the trash page
(physical page 0) is never handed out or refcounted, a failed
``alloc_upto`` rolls back atomically, and freeing a slot restores
``free_pages`` and zeroes its ``page_table`` row.

A seeded numpy fuzz always runs (so the invariants gate every PR even
without dev deps); when ``hypothesis`` is installed the same traces are
additionally explored generatively with shrinking.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev dep optional
    HAVE_HYPOTHESIS = False

from repro.configs import registry
from repro.serving import PagedKVCache, SwapManager

SLOTS, PAGES_PER_SLOT, PAGE = 3, 4, 4
MAX_LEN = PAGES_PER_SLOT * PAGE


def _tiny_cfg():
    # shrink every model dim; only the cache geometry matters here
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=1, num_heads=2, num_kv_heads=1, head_dim=8,
        attn_block=PAGE,
    )


def _check_invariants(
    kv: PagedKVCache, pins: dict[int, int] | None = None
) -> None:
    """``pins``: outstanding swap pins per page (page -> count) — a
    swapped-out sequence's shared prefix is kept live by references
    that no slot owns until resume. None asserts the no-pins steady
    state (refcount == number of owning slots exactly)."""
    pins = {p: n for p, n in (pins or {}).items() if n > 0}
    owned = [p for pages in kv._owned.values() for p in pages]
    counts: dict[int, int] = {}
    for p in owned:
        counts[p] = counts.get(p, 0) + 1
    # the trash page is never owned, freed, parked or refcounted
    assert 0 not in owned and 0 not in kv._free and 0 not in kv._cached
    assert kv._ref[0] == 0 and 0 not in pins
    # refcounts are never negative and (at op boundaries) equal the
    # number of slots mapping each page plus its outstanding swap pins
    # — in particular a refcount-1 unpinned page is owned by exactly
    # ONE slot
    assert (kv._ref >= 0).all()
    for p in range(1, kv.n_pages):
        assert kv._ref[p] == counts.get(p, 0) + pins.get(p, 0)
    # no slot maps the same page twice
    for pages in kv._owned.values():
        assert len(pages) == len(set(pages))
    # conservation: free ∪ owned ∪ cached ∪ pin-only == pool, disjoint
    pin_only = {p for p in pins if p not in counts}
    assert set(kv._free) | set(counts) | kv._cached | pin_only == set(
        range(1, kv.n_pages)
    )
    assert not set(kv._free) & (set(counts) | kv._cached | pin_only)
    assert not set(counts) & kv._cached
    assert not kv._cached & pin_only
    assert len(kv._free) == len(set(kv._free))
    assert kv.free_pages == (
        kv.n_pages - 1 - len(counts) - len(kv._cached) - len(pin_only)
    )
    # page_table rows mirror the owned lists, trash-padded
    for slot in range(kv.max_slots):
        pages = kv._owned.get(slot, [])
        assert list(kv.page_table[slot, : len(pages)]) == pages
        assert (kv.page_table[slot, len(pages):] == 0).all()


def _run_trace(ops) -> None:
    kv = PagedKVCache(_tiny_cfg(), max_slots=SLOTS, max_len=MAX_LEN)
    assert kv.n_pages == SLOTS * PAGES_PER_SLOT + 1
    for op, slot, pos in ops:
        if op == "alloc":
            before = len(kv._owned.get(slot, []))
            kv.alloc_upto(slot, pos)
            # monotone: never shrinks, backs exactly pos // page + 1
            assert len(kv._owned[slot]) == max(before, pos // PAGE + 1)
        else:
            kv.free_slot(slot)
            assert slot not in kv._owned
            assert (kv.page_table[slot] == 0).all()
        _check_invariants(kv)
    for slot in range(SLOTS):
        kv.free_slot(slot)
    # full teardown restores every page
    assert kv.free_pages == kv.n_pages - 1
    assert (kv.page_table == 0).all()


def _roundtrip(positions, slot) -> None:
    kv = PagedKVCache(_tiny_cfg(), max_slots=SLOTS, max_len=MAX_LEN)
    total = kv.free_pages
    for pos in positions:
        kv.alloc_upto(slot, pos)
    want = max(p // PAGE + 1 for p in positions)
    assert kv.free_pages == total - want
    assert (kv.page_table[slot, :want] > 0).all()
    kv.free_slot(slot)
    assert kv.free_pages == total
    assert (kv.page_table[slot] == 0).all()
    _check_invariants(kv)


@pytest.mark.parametrize("seed", range(8))
def test_alloc_free_trace_never_leaks_seeded(seed):
    rng = np.random.default_rng(seed)
    ops = [
        (
            "alloc" if rng.random() < 0.7 else "free",
            int(rng.integers(0, SLOTS)),
            int(rng.integers(0, MAX_LEN)),
        )
        for _ in range(int(rng.integers(5, 40)))
    ]
    _run_trace(ops)


@pytest.mark.parametrize("seed", range(4))
def test_alloc_free_roundtrip_seeded(seed):
    rng = np.random.default_rng(100 + seed)
    positions = [int(rng.integers(0, MAX_LEN)) for _ in range(int(rng.integers(1, 8)))]
    _roundtrip(positions, int(rng.integers(0, SLOTS)))


def _run_share_trace(ops, strategy=None) -> None:
    """Extended trace over the refcounted API: share (pin + adopt),
    copy-on-write splits, radix parking (free with a keep hook), LRU
    eviction, and host-memory swap round trips (swap_out pins the
    shared prefix, evacuates the rest, frees the slot; swap_in adopts
    the pinned prefix back and restores the host pages — mirroring the
    engine's preemption flow), with the full conservation/refcount
    invariant — including outstanding swap pins — checked after every
    op. ``tree`` models the prefix cache's page index.

    ``strategy`` runs the identical trace over a mesh-sharded pool
    (``PagedKVCache(strategy=)``): the allocator is host-side and
    layout-agnostic, so every invariant must hold unchanged while the
    device buffers live sharded across the mesh."""
    kv = PagedKVCache(
        _tiny_cfg(), max_slots=SLOTS, max_len=MAX_LEN, strategy=strategy
    )
    tree: set[int] = set()
    sm = SwapManager(kv, page_in_tree=lambda p: p in tree)
    records: list = []  # outstanding swap-outs
    pins: dict[int, int] = {}  # page -> live swap pins
    for op, slot, arg in ops:
        if op == "alloc":
            before = list(kv._owned.get(slot, []))
            try:
                kv.alloc_upto(slot, arg)
            except RuntimeError:
                # atomic: a failed grow must not retain anything
                assert kv._owned.get(slot, []) == before
        elif op == "free":
            if arg % 2:  # "insert": index the slot's pages, then park
                tree.update(kv._owned.get(slot, []))
            kv.free_slot(slot, keep=lambda p: p in tree)
        elif op == "share":
            src = arg % SLOTS
            src_pages = kv._owned.get(src, [])
            if slot != src and not kv._owned.get(slot) and src_pages:
                take = src_pages[: 1 + arg % len(src_pages)]
                for p in take:
                    kv.incref(p)
                kv.adopt(slot, take)
        elif op == "adopt_cached":
            if not kv._owned.get(slot) and kv._cached:
                take = sorted(kv._cached)[: 1 + arg % 3]
                for p in take:
                    kv.take_cached(p)
                kv.adopt(slot, take)
        elif op == "cow":
            owned = kv._owned.get(slot, [])
            li = arg % len(owned) if owned else 0
            if owned and kv._free and (
                kv.refcount(owned[li]) > 1 or owned[li] in tree
            ):
                old = owned[li]
                new = kv.cow_page(slot, li, keep=lambda p: p in tree)
                assert new != old and kv.refcount(new) == 1
        elif op == "evict":
            if kv._cached:
                victim = sorted(kv._cached)[arg % len(kv._cached)]
                kv.release_cached(victim)
                tree.discard(victim)
        elif op == "swap_out":
            if kv._owned.get(slot):
                rec = sm.swap_out(
                    slot, max_pin=arg % (PAGES_PER_SLOT + 1)
                )
                sm.finalize(rec)
                for p in rec.pin_pages:
                    pins[p] = pins.get(p, 0) + 1
                records.append(rec)
        elif op == "swap_in":
            tgt = next(
                (s for s in range(SLOTS) if not kv._owned.get(s)), None
            )
            if records and tgt is not None:
                rec = records[arg % len(records)]
                n_pin = len(rec.pin_pages)
                if kv.free_pages >= rec.n_logical - n_pin:
                    records.remove(rec)
                    # the engine's resume: the radix re-match pins the
                    # resident prefix, adopt turns those pins into the
                    # slot's references, fresh pages take the host copies
                    for p in rec.pin_pages:
                        kv.incref(p)
                    kv.adopt(tgt, list(rec.pin_pages))
                    kv.alloc_upto(tgt, rec.n_logical * PAGE - 1)
                    sm.swap_in(rec, tgt, n_resident=n_pin)
                    for p in rec.pin_pages:
                        pins[p] -= 1
        elif op == "discard":
            if records:
                rec = records.pop(arg % len(records))
                for p in rec.pin_pages:
                    pins[p] = pins.get(p, 0) - 1
                sm.discard(rec)
        _check_invariants(kv, pins)
    for rec in records:  # abandon outstanding swaps
        for p in rec.pin_pages:
            pins[p] = pins.get(p, 0) - 1
        sm.discard(rec)
    for slot in range(SLOTS):
        kv.free_slot(slot)  # no keep hook: nothing new parks
        _check_invariants(kv, pins)
    for p in sorted(kv._cached):
        kv.release_cached(p)
    assert kv.free_pages == kv.n_pages - 1
    assert (kv._ref == 0).all()


_SHARE_OPS = [
    "alloc", "free", "share", "adopt_cached", "cow", "evict",
    "swap_out", "swap_in", "discard",
]


@pytest.mark.parametrize("seed", range(8))
def test_share_cow_evict_trace_seeded(seed):
    rng = np.random.default_rng(200 + seed)
    ops = [
        (
            _SHARE_OPS[int(rng.integers(0, len(_SHARE_OPS)))],
            int(rng.integers(0, SLOTS)),
            int(rng.integers(0, MAX_LEN)),
        )
        for _ in range(int(rng.integers(10, 60)))
    ]
    _run_share_trace(ops)


def _mesh_strategy():
    """A (1, 8) tensor-parallel Strategy when the test process runs with
    8 simulated host devices (scripts/tier1.sh's mesh leg), else None.
    The tiny cfg's head_dim=8 divides tp=8, so the pool's last axis
    shards on the model axis."""
    import jax
    from jax.sharding import Mesh

    from repro.distributed import sharding as shd

    if len(jax.devices()) < 8:
        return None
    sub = np.asarray(jax.devices()[:8]).reshape(1, 8)
    return shd.Strategy(Mesh(sub, ("data", "model")), "tp")


@pytest.mark.parametrize("seed", range(4))
def test_share_cow_evict_trace_sharded_pool(seed):
    """The full share/COW/park/evict/swap fuzz over a pool sharded
    across a simulated 8-device mesh: refcount conservation and swap pin
    semantics are host-side bookkeeping and must be identical whatever
    the device layout — COW's jit'd page copy and the swap manager's
    gather/scatter run on sharded buffers."""
    st = _mesh_strategy()
    if st is None:
        pytest.skip(
            "needs XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )
    rng = np.random.default_rng(700 + seed)
    ops = [
        (
            _SHARE_OPS[int(rng.integers(0, len(_SHARE_OPS)))],
            int(rng.integers(0, SLOTS)),
            int(rng.integers(0, MAX_LEN)),
        )
        for _ in range(int(rng.integers(10, 60)))
    ]
    _run_share_trace(ops, strategy=st)


def test_capacity_and_exhaustion_errors():
    kv = PagedKVCache(_tiny_cfg(), max_slots=SLOTS, max_len=MAX_LEN)
    with pytest.raises(ValueError):
        kv.alloc_upto(0, MAX_LEN)  # beyond per-slot capacity
    # freeing an unallocated slot is a no-op, not an error
    kv.free_slot(1)
    _check_invariants(kv)
    # drain the pool: allocation must fail loudly, not hand out trash
    for slot in range(SLOTS):
        kv.alloc_upto(slot, MAX_LEN - 1)
    assert kv.free_pages == 0
    kv.free_slot(0)
    kv._free.clear()  # simulate exhaustion with slot 0 unbacked
    with pytest.raises(RuntimeError):
        kv.alloc_upto(0, 0)
    assert 0 not in [p for ps in kv._owned.values() for p in ps]


if HAVE_HYPOTHESIS:

    @given(
        ops=st.lists(
            st.tuples(
                st.sampled_from(["alloc", "free"]),
                st.integers(0, SLOTS - 1),
                st.integers(0, MAX_LEN - 1),
            ),
            max_size=40,
        )
    )
    @settings(max_examples=25, deadline=None)
    def test_alloc_free_trace_never_leaks(ops):
        _run_trace(ops)

    @given(
        positions=st.lists(
            st.integers(0, MAX_LEN - 1), min_size=1, max_size=8
        ),
        slot=st.integers(0, SLOTS - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_alloc_free_roundtrip_restores_free_pages(positions, slot):
        _roundtrip(positions, slot)

    @given(
        ops=st.lists(
            st.tuples(
                st.sampled_from(_SHARE_OPS),
                st.integers(0, SLOTS - 1),
                st.integers(0, MAX_LEN - 1),
            ),
            max_size=60,
        )
    )
    @settings(max_examples=25, deadline=None)
    def test_share_cow_evict_trace(ops):
        _run_share_trace(ops)
