"""Serving subsystem: scheduler, paged KV cache, engine vs Server oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Server
from repro.models import transformer as T
from repro.serving import (
    Engine,
    EngineConfig,
    PagedKVCache,
    Request,
    Scheduler,
)


def _smoke_cfg(**kw):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=2, vocab_size=128, **kw
    )


# ----------------------------------------------------------------------
# Scheduler (no model)
# ----------------------------------------------------------------------


def test_scheduler_admits_and_evicts_under_trace():
    sch = Scheduler(2)
    reqs = [
        Request(i, np.array([1, 2, 3]), max_new_tokens=4) for i in range(5)
    ]
    for r in reqs[:3]:
        sch.submit(r)
    # only two slots: third request stays queued
    s0 = sch.admit(step=0)
    s1 = sch.admit(step=0)
    assert (s0.slot, s1.slot) == (0, 1)
    assert sch.admit(step=0) is None
    assert sch.occupancy == 1.0 and len(sch.waiting) == 1
    # evicting frees the slot for the queued request, mid-flight
    sch.evict(0)
    assert sch.occupancy == 0.5
    s2 = sch.admit(step=3)
    assert s2.slot == 0 and s2.request.uid == 2 and s2.admit_step == 3
    # late arrivals join the same queue
    for r in reqs[3:]:
        sch.submit(r)
    sch.evict(1)
    assert sch.admit(step=5).request.uid == 3
    assert not sch.idle
    sch.evict(0), sch.evict(1)
    assert sch.admit(step=6).request.uid == 4
    sch.evict(0)
    assert sch.idle


def test_scheduler_evict_empty_slot_raises():
    sch = Scheduler(1)
    with pytest.raises(ValueError):
        sch.evict(0)


# ----------------------------------------------------------------------
# Paged KV cache
# ----------------------------------------------------------------------


def test_paged_cache_page_accounting():
    cfg = _smoke_cfg()
    kv = PagedKVCache(cfg, max_slots=2, max_len=4 * cfg.attn_block)
    total = kv.free_pages
    assert kv.n_pages == 2 * 4 + 1
    kv.alloc_upto(0, 0)
    kv.alloc_upto(0, 3 * kv.page)  # pages 0..3
    assert kv.free_pages == total - 4
    assert (kv.page_table[0, :4] > 0).all()  # page 0 is reserved (trash)
    kv.free_slot(0)
    assert kv.free_pages == total and (kv.page_table[0] == 0).all()
    with pytest.raises(ValueError):
        kv.alloc_upto(1, 4 * kv.page)  # beyond per-slot capacity


def test_paged_prefill_roundtrips_vs_contiguous_cache():
    """prefill_paged writes the same K/V the contiguous prefill produces,
    page-scattered; gathering the slot's pages reconstructs them."""
    cfg = _smoke_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    page = cfg.attn_block
    plen = page  # one full page: no padding ambiguity
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, plen, dtype=np.int32
    )

    # contiguous reference: prefill mode keeps the raw K/V
    _, ref = T.prefill(cfg, params, {"tokens": jnp.asarray(prompt[None])})

    kv = PagedKVCache(cfg, max_slots=2, max_len=2 * page)
    kv.alloc_upto(1, plen - 1)  # slot 1: catches slot/page mix-ups
    rows = jnp.asarray(kv.table_row(1, 1))[None]  # (N=1, P=1)
    _, kv.buffers = T.prefill_paged(
        cfg, params, jnp.asarray(prompt[None]),
        jnp.asarray([plen], jnp.int32), kv.buffers, rows,
    )
    for pool, r in zip(kv.buffers, ref):
        for name in ("k", "v"):
            # gather the slot's page back into (count, S, hk, d)
            got = np.asarray(pool[name][:, kv.page_table[1, 0]])
            want = np.asarray(r[name][:, 0, :plen])
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_paged_prefill_batched_matches_per_request():
    """One (N, S) prefill call writes each request's pages exactly as N
    separate (1, S) calls would, and returns per-request last-real-token
    logits; bucket padding scatters only to the trash page."""
    cfg = _smoke_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    page = cfg.attn_block
    s = 2 * page
    rng = np.random.default_rng(3)
    plens = [page // 2, 2 * page, page + 3]  # ragged, crossing a page
    prompts = [
        rng.integers(0, cfg.vocab_size, p).astype(np.int32) for p in plens
    ]

    # reference: one call per request into its own cache
    kv_ref = PagedKVCache(cfg, max_slots=4, max_len=s)
    ref_logits = []
    for i, (pl, pr) in enumerate(zip(plens, prompts)):
        kv_ref.alloc_upto(i, pl - 1)
        tokens = np.zeros((1, s), np.int32)
        tokens[0, :pl] = pr
        lg, kv_ref.buffers = T.prefill_paged(
            cfg, params, jnp.asarray(tokens), jnp.asarray([pl], jnp.int32),
            kv_ref.buffers, jnp.asarray(kv_ref.bucket_row(i, pl, 2))[None],
        )
        ref_logits.append(np.asarray(lg[0]))

    # batched: N=4 (one padding row), same physical page layout
    kv_b = PagedKVCache(cfg, max_slots=4, max_len=s)
    tokens = np.zeros((4, s), np.int32)
    plens_b = np.ones((4,), np.int32)
    rows = np.zeros((4, 2), np.int32)
    for i, (pl, pr) in enumerate(zip(plens, prompts)):
        kv_b.alloc_upto(i, pl - 1)
        tokens[i, :pl] = pr
        plens_b[i] = pl
        rows[i] = kv_b.bucket_row(i, pl, 2)
    logits, kv_b.buffers = T.prefill_paged(
        cfg, params, jnp.asarray(tokens), jnp.asarray(plens_b),
        kv_b.buffers, jnp.asarray(rows),
    )
    assert logits.shape[0] == 4
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(logits[i]), ref_logits[i], rtol=1e-5, atol=1e-5
        )
    for pool_b, pool_r in zip(kv_b.buffers, kv_ref.buffers):
        for name in ("k", "v"):
            # identical allocation order -> identical physical pages;
            # compare every real (non-trash) page
            np.testing.assert_allclose(
                np.asarray(pool_b[name][:, 1:]),
                np.asarray(pool_r[name][:, 1:]),
                rtol=1e-6,
                atol=1e-6,
            )


# ----------------------------------------------------------------------
# Engine
# ----------------------------------------------------------------------


@pytest.mark.parametrize("sparse", [False, True])
def test_engine_matches_server_greedy(sparse):
    """Greedy tokens must match the Server oracle exactly. The sparse case
    is exact too: with 2 pages per slot the butterfly/local/global window
    covers every causal block, so the engine's sparse prefill + paged
    sparse decode equal dense attention — which is also what the Server
    computes (its ragged cache falls back to dense decode)."""
    cfg = _smoke_cfg(sparse_attention=sparse)
    mesh = make_local_mesh()
    server = Server(cfg, mesh)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(3, 8), dtype=np.int32
    )
    ref = server.generate(prompts, 5)

    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=3, max_len=128),
        params=server.params,
    )
    for b in range(3):
        eng.submit(prompts[b], 5)
    fins = sorted(eng.drain(max_steps=50), key=lambda f: f.uid)
    out = np.stack([f.tokens for f in fins])
    np.testing.assert_array_equal(out, ref)


def test_engine_matches_server_adversarial_schedule():
    """Randomized (seeded) admission order, interleaved stepping, and
    mid-stream slot reuse must still reproduce the fixed-batch Server's
    greedy tokens exactly, per request. With 2 slots and 6 requests every
    slot is reused multiple times, and random step() bursts between
    submissions shuffle which requests share a decode batch."""
    cfg = _smoke_cfg(sparse_attention=True)
    mesh = make_local_mesh()
    server = Server(cfg, mesh)
    rng = np.random.default_rng(7)
    plens, gens = [8, 16], [3, 5]
    reqs = [
        (
            rng.integers(0, cfg.vocab_size, plens[i % 2]).astype(np.int32),
            gens[int(rng.integers(0, 2))],
        )
        for i in range(6)
    ]
    # oracle batched per prompt length; greedy decode is append-only, so
    # generating max(gens) once covers every per-request gen length
    ref = {}
    for plen in plens:
        ids = [i for i, (p, _) in enumerate(reqs) if p.size == plen]
        out = server.generate(
            np.stack([reqs[i][0] for i in ids]), max(gens)
        )
        for row, i in enumerate(ids):
            ref[i] = out[row, : reqs[i][1]]

    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=128),
        params=server.params,
    )
    order = list(range(6))
    rng.shuffle(order)  # adversarial: admission order != submission order
    uids = {}
    fins = []
    while order:
        k = int(rng.integers(1, 3))
        for i in order[:k]:
            uids[eng.submit(*reqs[i])] = i
        order = order[k:]
        for _ in range(int(rng.integers(0, 4))):  # random step bursts
            fins += eng.step()
    fins += eng.drain(max_steps=200)

    assert sorted(f.uid for f in fins) == sorted(uids)
    # slot reuse actually happened mid-stream
    assert max(f.admit_step for f in fins) > min(
        f.finish_step for f in fins
    )
    for f in fins:
        np.testing.assert_array_equal(f.tokens, ref[uids[f.uid]])


def test_engine_continuous_batching_mixed_lengths():
    """More requests than slots, ragged lengths, late arrivals: everything
    finishes, pages don't leak, and slots refill mid-flight."""
    cfg = _smoke_cfg(sparse_attention=True)
    eng = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(max_slots=2, max_len=128),
    )
    rng = np.random.default_rng(1)
    gens: dict[int, int] = {}
    for _ in range(3):
        gen = int(rng.integers(2, 7))
        uid = eng.submit(
            rng.integers(0, cfg.vocab_size, int(rng.integers(2, 40))), gen
        )
        gens[uid] = gen
    fins = []
    for _ in range(3):
        fins += eng.step()
    late = eng.submit(rng.integers(0, cfg.vocab_size, 5), 3)
    gens[late] = 3
    fins += eng.drain(max_steps=100)

    assert sorted(f.uid for f in fins) == sorted(gens)
    for f in fins:
        assert f.finish_reason == "length"
        assert len(f.tokens) == gens[f.uid]
    # some admission happened after step 0 (continuous batching)
    assert max(f.admit_step for f in fins) > 0
    # all pages returned to the free list
    assert eng.kv.free_pages == eng.kv.n_pages - 1
    assert eng.scheduler.idle
    assert eng.stats_summary()["mean_occupancy"] > 0


def test_engine_non_pow2_bucket_matches_server():
    """Regression: max_len=192 (3 pages) makes a non-power-of-two bucket
    whose 192-token prefill used to trip ``assert sk % chunk == 0`` in
    flash_attention_jnp (attn_chunk=128). A 140-token prompt must serve
    and match the Server oracle on the dense smoke config."""
    cfg = _smoke_cfg()
    assert 192 % cfg.attn_chunk != 0  # the shape that used to crash
    mesh = make_local_mesh()
    server = Server(cfg, mesh)
    prompt = np.random.default_rng(5).integers(
        0, cfg.vocab_size, 140, dtype=np.int32
    )
    ref = server.generate(prompt[None], 4)[0]
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=1, max_len=192),
        params=server.params,
    )
    eng.submit(prompt, 4)
    fins = eng.drain(max_steps=30)
    np.testing.assert_array_equal(fins[0].tokens, ref)


def test_engine_batched_admission_single_prefill_call():
    """A same-bucket group of N waiting requests is admitted by ONE jit'd
    prefill call (tokens (N, S)) and one host sync; the per-request
    baseline (max_prefill_batch=1) issues N calls on the same trace."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab_size, size=(4, 8), dtype=np.int32
    )

    def serve(engine):
        calls = []
        orig = engine._prefill

        def counting(*a):
            calls.append(tuple(a[1].shape))  # tokens shape
            return orig(*a)

        engine._prefill = counting
        for b in range(4):
            engine.submit(prompts[b], 3)
        fins = engine.drain(max_steps=30)
        return calls, sorted(fins, key=lambda f: f.uid)

    eng = Engine(
        cfg, mesh, engine_cfg=EngineConfig(max_slots=4, max_len=64)
    )
    calls, fins = serve(eng)
    assert calls == [(4, 64)]  # one (N, S) program, one call
    assert eng.stats_summary()["prefill_calls"] == 1
    assert eng.stats_summary()["mean_prefill_batch"] == 4.0
    assert eng.stats_summary()["prefill_by_bucket"] == {
        "4x64": {"calls": 1, "requests": 4}
    }

    base = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(
            max_slots=4, max_len=64, max_prefill_batch=1
        ),
        params=eng.params,
    )
    bcalls, bfins = serve(base)
    assert bcalls == [(1, 64)] * 4
    # same greedy tokens either way
    for f, g in zip(fins, bfins):
        np.testing.assert_array_equal(f.tokens, g.tokens)


def test_engine_batched_ragged_buckets_match_server():
    """Batched admission with ragged prompt lengths crossing bucket
    boundaries (1-, 2- and 4-page buckets admitted in the same step) must
    reproduce the Server oracle per request."""
    cfg = _smoke_cfg(sparse_attention=True)
    mesh = make_local_mesh()
    server = Server(cfg, mesh)
    rng = np.random.default_rng(11)
    page = cfg.attn_block
    plens = [8, page - 1, page + 5, 2 * page + 9, 3, 2 * page]
    reqs = [
        rng.integers(0, cfg.vocab_size, p).astype(np.int32) for p in plens
    ]
    ref = {}
    for plen in sorted(set(plens)):
        ids = [i for i, p in enumerate(plens) if p == plen]
        out = server.generate(np.stack([reqs[i] for i in ids]), 4)
        for row, i in enumerate(ids):
            ref[i] = out[row]

    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=6, max_len=4 * page),
        params=server.params,
    )
    uids = {eng.submit(reqs[i], 4): i for i in range(6)}
    fins = eng.drain(max_steps=40)
    assert len(fins) == 6
    # several buckets were in flight in the same admission pass
    assert len(eng.stats_summary()["prefill_by_bucket"]) >= 3
    for f in fins:
        np.testing.assert_array_equal(f.tokens, ref[uids[f.uid]])


def test_engine_lookahead_admits_past_oversized_request():
    """Page-pressure admission: with an oversubscribed page pool, an
    oversized head-of-queue request must not head-of-line-block smaller
    ones behind it — lookahead admits them first, and the big one lands
    once pages free up. Tokens still match the oracle."""
    cfg = _smoke_cfg(sparse_attention=True)
    mesh = make_local_mesh()
    server = Server(cfg, mesh)
    page = cfg.attn_block
    rng = np.random.default_rng(13)
    big = rng.integers(0, cfg.vocab_size, 2 * page + 4).astype(np.int32)
    small = [
        rng.integers(0, cfg.vocab_size, 6 + i).astype(np.int32)
        for i in range(2)
    ]
    ref_big = server.generate(big[None], 3)[0]
    ref_small = [server.generate(p[None], 3)[0] for p in small]

    # slots=3, 5 usable pages (pool oversubscribed vs worst-case 9):
    # hog 3 pages first so the 3-page "big" request cannot be admitted
    # while the two 1-page smalls behind it still can
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=3, max_len=3 * page, n_pages=6),
        params=server.params,
    )
    hog = rng.integers(0, cfg.vocab_size, 2 * page + 4).astype(np.int32)
    eng.submit(hog, 8)
    eng.step()  # hog admitted: 3 of 5 usable pages taken
    uid_big = eng.submit(big, 3)
    uid_small = [eng.submit(p, 3) for p in small]
    fins = eng.step()
    # big (3 pages) skipped, both smaller ones (1 page each) admitted
    active_uids = {s.request.uid for s in eng.scheduler.active()}
    assert uid_big not in active_uids
    assert set(uid_small) <= active_uids
    fins += eng.drain(max_steps=60)
    by_uid = {f.uid: f for f in fins}
    assert by_uid[uid_big].admit_step > max(
        by_uid[u].admit_step for u in uid_small
    )
    np.testing.assert_array_equal(by_uid[uid_big].tokens, ref_big)
    for u, r in zip(uid_small, ref_small):
        np.testing.assert_array_equal(by_uid[u].tokens, r)


def test_engine_oversubscribed_pool_survives_decode_growth():
    """Regression: admission budgets a request's *lifetime* pages (prompt
    + decode growth), not just the prompt. With a 2-usable-page pool and
    two one-page prompts that each grow into a second page mid-decode,
    naive prompt-only budgeting admits both and crashes ``alloc_upto``
    with 'KV cache out of pages'; lifetime budgeting serializes them and
    every request finishes."""
    cfg = _smoke_cfg()
    page = cfg.attn_block
    eng = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(max_slots=2, max_len=2 * page, n_pages=3),
    )
    rng = np.random.default_rng(17)
    uids = [
        eng.submit(
            rng.integers(0, cfg.vocab_size, page).astype(np.int32), 4
        )
        for _ in range(2)
    ]
    fins = eng.drain(max_steps=60)  # must not raise
    assert sorted(f.uid for f in fins) == sorted(uids)
    assert all(len(f.tokens) == 4 for f in fins)
    # sequential admission under page pressure, then full cleanup
    assert fins[0].admit_step != fins[1].admit_step
    assert eng.kv.free_pages == eng.kv.n_pages - 1
    assert not eng._page_need


def test_engine_eos_and_capacity_finish():
    cfg = _smoke_cfg()
    eng = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(max_slots=1, max_len=64),
    )
    prompt = np.arange(8, dtype=np.int32)
    # learn the greedy stream, then replay with one of its tokens as eos
    eng.submit(prompt, 4)
    toks = [int(t) for t in eng.drain(max_steps=30)[0].tokens]
    eos = toks[-1]
    k = toks.index(eos)  # greedy replay stops at its first occurrence
    eng.submit(prompt, 4, eos_id=eos)
    fin = eng.drain(max_steps=30)[0]
    assert fin.finish_reason == "eos" and len(fin.tokens) == k + 1
    # capacity: request asks for more tokens than the slot can hold
    eng.submit(np.arange(60, dtype=np.int32), 50)
    fin = eng.drain(max_steps=30)[0]
    assert fin.finish_reason == "capacity"
    assert 60 + len(fin.tokens) <= 64 + 1
