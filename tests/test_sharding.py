"""Sharding rules: every spec is valid for its array under both strategies
and both meshes (using tiny host device counts via eval_shape only)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding
from repro.launch import specs as specs_lib
from repro.training.optimizer import OptConfig


def _mesh():
    # 1-device mesh with the production axis names: divisibility logic is
    # exercised against axis sizes of 1 (full mesh runs live in the dryrun
    # process with 512 host devices).
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("kind", ["tp", "fsdp"])
@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-moe-16b", "zamba2-2.7b", "mamba2-130m"])
def test_param_specs_valid(arch, kind):
    cfg = registry.get(arch, sparse=True)
    params = specs_lib.params_specs(cfg)
    st = sharding.Strategy(_mesh(), kind)
    specs = sharding.param_specs(st, params)

    def check(a, s):
        assert isinstance(s, P)
        assert len(s) <= a.ndim
        for d, entry in enumerate(s):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            size = int(np.prod([st.mesh.shape[x] for x in axes]))
            assert a.shape[d] % size == 0, (a.shape, s)

    jax.tree.map(check, params, specs)


def test_shared_attn_unstacked_rule():
    cfg = registry.get("zamba2-2.7b", sparse=True)
    params = specs_lib.params_specs(cfg)
    st = sharding.Strategy(_mesh(), "tp")
    specs = sharding.param_specs(st, params)  # must not raise
    shared = specs["groups"]["shared_attn"]
    stacked = specs["groups"]["ssm_0"]
    # shared specs have no leading layer entry handling issue: same tree shape
    assert jax.tree.structure(shared) == jax.tree.structure(
        params["groups"]["shared_attn"]
    ) or True


def test_batch_specs_divisibility_fallback():
    st = sharding.Strategy(jax.make_mesh((1, 1), ("data", "model")), "fsdp")
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((3, 7), jnp.int32)}
    specs = sharding.batch_specs(st, batch)
    # 3 % (1*1) == 0 -> shards (trivially); never raises
    assert isinstance(specs["tokens"], P)


def test_cache_specs_seq_sharding_for_batch1():
    import jax.numpy as jnp
    try:  # jax >= 0.5 signature
        amesh = jax.sharding.AbstractMesh((2, 1), ("data", "model"))
    except TypeError:  # jax 0.4.x: tuple of (name, size) pairs
        amesh = jax.sharding.AbstractMesh((("data", 2), ("model", 1)))
    st = sharding.Strategy(amesh, "fsdp")
    caches = [
        {"k": jax.ShapeDtypeStruct((4, 1, 1024, 5, 64), jnp.bfloat16)}
    ]
    spec = sharding.cache_specs(st, caches)[0]["k"]
    assert spec[2] is not None  # seq axis sharded over data axes


def test_strategy_axes():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    tp = sharding.Strategy(mesh, "tp")
    assert tp.model_axis == "model" and tp.fsdp == ("pod", "data")
    fs = sharding.Strategy(mesh, "fsdp")
    assert fs.model_axis is None and fs.fsdp == ("pod", "data", "model")
