"""Live telemetry plane: rolling windows, SLO burn-rate monitor with
load-shed, flight recorder, and the /metrics scrape endpoint.

The load-bearing contracts:

  * ``WindowedView`` answers "over the last N seconds" from registry
    deltas without touching a single hot-path record call, ages history
    out, and restarts cleanly when ``reset_stats()`` swaps the registry
    (registry *identity* is the reset protocol).
  * The burn-rate monitor is the multi-window AND: both the fast and
    the slow window must burn for an alert, shed rejections never count
    as SLO errors (the monitor's own response must not latch CRITICAL).
  * Monitoring alone never changes a token stream; with ``shed=True``
    overload surfaces as structured ``REJECT_SHED`` results, never
    silent drops.
  * One injected step-time spike produces exactly one incident bundle
    whose trace (counter lanes included) passes ``validate_trace_file``.
  * A concurrent ``/metrics`` scrape racing ``Engine.reset_stats()``
    always sees a parseable exposition, never a torn one.
"""

import json
import math
import threading
import urllib.request

import numpy as np
import pytest

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.obs import (
    BurnRateMonitor,
    FlightRecorder,
    MetricsRegistry,
    SloConfig,
    SpikeDetector,
    WindowedView,
    validate_trace_file,
)
from repro.obs.http import MetricsServer, attach, split_listen
from repro.obs.perfetto import TraceValidationError, validate_trace
from repro.obs.prom import parse, render
from repro.obs.slo import CRITICAL, OK, WARN
from repro.obs.windows import Ewma, merged_percentile
from repro.serving import Engine, EngineConfig, ScheduleParams
from repro.serving.request import REJECT_SHED


def _smoke_cfg(**kw):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=2, vocab_size=128, **kw
    )


class _Clock:
    """Deterministic monotonic clock for window tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


# ----------------------------------------------------------------------
# Rolling windows (no engine)
# ----------------------------------------------------------------------


def test_ewma_warmup_and_value():
    e = Ewma(alpha=0.5)
    assert e.value == 0.0 and e.n == 0
    e.update(10.0)
    assert e.value == 10.0
    e.update(0.0)
    assert e.value == pytest.approx(5.0) and e.n == 2
    with pytest.raises(ValueError):
        Ewma(alpha=0.0)


def test_windowed_view_deltas_rates_and_span():
    clk = _Clock()
    reg = MetricsRegistry()
    c = reg.counter("c_total", "")
    h = reg.histogram("h_seconds", "")
    w = WindowedView(lambda: reg, window_s=10.0, n_buckets=10, now_fn=clk)
    for i in range(10):
        clk.t = float(i)
        c.inc(2)
        h.observe(0.01 * (i + 1))
        w.tick()
    assert w.delta("c_total") == 20
    assert w.rate("c_total") == pytest.approx(20 / 9.0)
    # span-limited query covers the buckets overlapping the last 3 s
    # (resolution = one bucket width, per the docstring)
    assert 6 <= w.delta("c_total", span_s=3.0) <= 8
    assert w.percentile("h_seconds", 50) == pytest.approx(0.055)
    assert len(w.samples("h_seconds", span_s=2.0)) <= 3
    assert w.covered_s == pytest.approx(9.0)
    # old buckets age out entirely
    clk.t = 30.0
    w.tick()
    assert w.delta("c_total") == 0
    assert w.samples("h_seconds") == []


def test_windowed_view_labeled_counter_deltas():
    clk = _Clock()
    reg = MetricsRegistry()
    c = reg.counter("r_total", "", labelname="reason")
    w = WindowedView(lambda: reg, window_s=10.0, n_buckets=5, now_fn=clk)
    w.tick()
    c.inc(3, label="shed")
    c.inc(1, label="timeout")
    clk.t = 1.0
    w.tick()
    assert w.delta("r_total") == 4
    assert w.delta("r_total", label="shed") == 3
    assert w.delta("r_total", label="timeout") == 1


def test_windowed_view_registry_swap_resets():
    """reset_stats() swaps the registry object; the view must drop
    retained history (pre-reset samples never leak post-reset)."""
    clk = _Clock()
    reg1 = MetricsRegistry()
    reg1.counter("c_total", "").inc(100)
    reg1.histogram("h_seconds", "").observe(9.9)
    box = {"reg": reg1}
    w = WindowedView(
        lambda: box["reg"], window_s=10.0, n_buckets=5, now_fn=clk
    )
    w.tick()  # seeds cursors at 0 -> the pre-existing 100 lands here
    assert w.delta("c_total") == 100
    reg2 = MetricsRegistry()
    reg2.counter("c_total", "").inc(7)
    box["reg"] = reg2
    clk.t = 1.0
    w.tick()
    assert w.delta("c_total") == 7
    assert w.samples("h_seconds") == []


def test_windowed_view_stalled_ticks_restart():
    """A tick gap longer than the whole window restarts the ring
    instead of spinning through hundreds of empty buckets."""
    clk = _Clock()
    reg = MetricsRegistry()
    c = reg.counter("c_total", "")
    w = WindowedView(lambda: reg, window_s=5.0, n_buckets=5, now_fn=clk)
    c.inc(5)
    w.tick()
    clk.t = 1e6
    c.inc(1)
    w.tick()
    assert w.delta("c_total") == 1
    assert len(w._buckets) == 1


def test_merged_percentile_is_true_fleet_percentile():
    clk = _Clock()
    views = []
    for samples in ([0.001] * 9, [1.0]):
        reg = MetricsRegistry()
        h = reg.histogram("h_seconds", "")
        for s in samples:
            h.observe(s)
        v = WindowedView(lambda r=reg: r, window_s=10.0, now_fn=clk)
        v.tick()
        views.append(v)
    # average-of-averages would put p50 near 0.5; the truth is 0.001
    assert merged_percentile(views, "h_seconds", 50) == pytest.approx(
        0.001
    )
    assert merged_percentile(views, "nope_seconds", 50) == 0.0


# ----------------------------------------------------------------------
# Metrics edge cases (zero samples, mixed labels)
# ----------------------------------------------------------------------


def test_counter_value_sums_base_and_labeled_increments():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "", labelname="kind")
    c.inc(5)  # base (unlabeled) increments
    c.inc(3, label="x")
    assert c.value == 8 and c.get("x") == 3


def test_empty_histogram_zero_sample_contract():
    reg = MetricsRegistry()
    h = reg.histogram("h_seconds", "")
    assert h.count == 0 and h.sum == 0.0
    assert h.percentile(99) == 0.0
    assert h.mean() == 0.0 and h.min() == 0.0 and h.max() == 0.0
    # the exposition of a sample-free registry still parses
    flat = parse(render(reg))
    assert flat["h_seconds_count"] == 0


def test_prom_render_non_finite_values_parse():
    reg = MetricsRegistry()
    reg.gauge("g_nan", "").set(float("nan"))
    reg.gauge("g_inf", "").set(float("inf"))
    flat = parse(render(reg))
    assert math.isnan(flat["g_nan"]) and flat["g_inf"] == math.inf


# ----------------------------------------------------------------------
# Burn-rate monitor
# ----------------------------------------------------------------------


def _slo_fixture(clk, cfg):
    reg = MetricsRegistry()
    total = reg.counter("repro_serve_slo_requests_total", "")
    met = reg.counter("repro_serve_slo_met_total", "")
    fin = reg.counter("repro_serve_requests_finished_total", "")
    rej = reg.counter(
        "repro_serve_rejected_total", "", labelname="reason"
    )
    w = WindowedView(
        lambda: reg, window_s=cfg.slow_window_s, n_buckets=10, now_fn=clk
    )
    return BurnRateMonitor(w, cfg), total, met, fin, rej, w


def test_slo_config_validation():
    with pytest.raises(ValueError):
        SloConfig(target=1.0)
    with pytest.raises(ValueError):
        SloConfig(fast_window_s=10.0, slow_window_s=5.0)
    with pytest.raises(ValueError):
        SloConfig(warn_burn=3.0, critical_burn=2.0)
    with pytest.raises(ValueError):
        SloConfig(shed_max_per_tick=0)


def test_burn_monitor_window_too_short_raises():
    clk = _Clock()
    reg = MetricsRegistry()
    w = WindowedView(lambda: reg, window_s=1.0, now_fn=clk)
    with pytest.raises(ValueError):
        BurnRateMonitor(w, SloConfig(slow_window_s=60.0))


def test_burn_monitor_state_machine_and_transitions():
    clk = _Clock()
    cfg = SloConfig(
        target=0.9, fast_window_s=2.0, slow_window_s=10.0,
        warn_burn=2.0, critical_burn=6.0,
    )
    mon, total, met, fin, rej, w = _slo_fixture(clk, cfg)
    # healthy traffic: 100% attainment
    total.inc(10)
    met.inc(10)
    w.tick()
    s = mon.evaluate()
    assert s["state"] == OK and s["transitioned_to"] is None
    # a wave of misses: 20/30 errors = burn 6.7 >= critical in both
    # windows (the healthy batch is still retained in each)
    clk.t = 1.0
    total.inc(20)
    w.tick()
    s = mon.evaluate()
    assert s["state"] == CRITICAL and s["transitioned_to"] == CRITICAL
    assert s["fast_burn"] >= 6.0 and s["slow_burn"] >= 6.0
    # a second CRITICAL evaluation is steady state, not a transition
    s = mon.evaluate()
    assert s["state"] == CRITICAL and s["transitioned_to"] is None
    assert mon.transitions[CRITICAL] == 1
    # errors age out of both windows -> recovery
    clk.t = 100.0
    total.inc(20)
    met.inc(19)  # 5% misses on a 10% budget: burn 0.5
    w.tick()
    s = mon.evaluate()
    assert s["state"] == OK
    assert mon.last is s  # /slo reads the retained result


def test_burn_monitor_multiwindow_and_rule():
    """A fast-window blip must NOT alert while the slow window is
    healthy — state follows min(fast, slow)."""
    clk = _Clock()
    cfg = SloConfig(
        target=0.9, fast_window_s=1.0, slow_window_s=10.0,
        warn_burn=2.0, critical_burn=6.0,
    )
    mon, total, met, fin, rej, w = _slo_fixture(clk, cfg)
    # 9 s of perfect traffic fills the slow window
    for i in range(9):
        clk.t = float(i)
        total.inc(10)
        met.inc(10)
        w.tick()
    # one bad second: fast window burns hard (20/30 = burn 6.7; one
    # healthy bucket rides along at this resolution), slow window stays
    # under warn (20/110 = burn 1.8)
    clk.t = 9.0
    total.inc(20)
    w.tick()
    s = mon.evaluate()
    assert s["windows"]["fast"]["burn"] >= 6.0
    assert s["windows"]["slow"]["burn"] < 2.0
    assert s["state"] == OK


def test_burn_monitor_fallback_excludes_shed_rejections():
    """No deadline'd traffic: burn falls back to the non-shed rejection
    fraction.  Shed rejections are the monitor's own output and never
    count as errors (no CRITICAL latch)."""
    clk = _Clock()
    cfg = SloConfig(
        target=0.9, fast_window_s=2.0, slow_window_s=10.0,
        warn_burn=2.0, critical_burn=6.0,
    )
    mon, total, met, fin, rej, w = _slo_fixture(clk, cfg)
    fin.inc(10)
    rej.inc(50, label="shed")
    w.tick()
    s = mon.evaluate()
    assert s["state"] == OK and s["fast_burn"] == 0.0
    # real (timeout) rejections do burn
    rej.inc(10, label="timeout")
    clk.t = 0.5
    w.tick()
    s = mon.evaluate()
    assert s["fast_burn"] >= 2.0 and s["state"] in (WARN, CRITICAL)


# ----------------------------------------------------------------------
# Spike detection + flight recorder (no engine)
# ----------------------------------------------------------------------


def test_spike_detector_warmup_fire_cooldown_adapt():
    d = SpikeDetector(factor=4.0, min_samples=8, cooldown=4)
    for _ in range(8):
        assert not d.observe(0.01)
    assert d.observe(1.0)  # spike fires once
    assert not d.observe(1.0)  # refractory; spike folds into EWMA
    for _ in range(10):
        d.observe(1.0)
    # the regression became the new baseline: no more firing
    assert not d.observe(1.0)
    assert d.fired == 1
    with pytest.raises(ValueError):
        SpikeDetector(factor=1.0)


def test_spike_detector_min_value_floor():
    d = SpikeDetector(factor=2.0, min_samples=2, min_value=0.5)
    d.observe(0.001)
    d.observe(0.001)
    assert not d.observe(0.01)  # 10x the baseline but under the floor
    assert d.observe(0.6)


def test_flight_recorder_bundle_debounce_and_cap(tmp_path):
    clk = _Clock()
    reg = MetricsRegistry()
    reg.counter("c_total", "").inc(3)
    fr = FlightRecorder(
        tmp_path / "fl", min_interval_s=1.0, max_bundles=2, clock=clk
    )
    p1 = fr.capture("spike", metrics=reg, config={"k": 1},
                    context={"v": 2.0})
    assert p1 is not None
    man = json.loads((tmp_path / "fl").joinpath(
        p1.rsplit("/", 1)[-1], "manifest.json").read_text())
    assert man["kind"] == "spike" and man["config"] == {"k": 1}
    assert parse((tmp_path / "fl").joinpath(
        p1.rsplit("/", 1)[-1], "metrics.prom").read_text())[
        "c_total"] == 3
    # same kind inside min_interval_s: debounced
    clk.t = 0.5
    assert fr.capture("spike", metrics=reg) is None
    # a different kind is not debounced by the first
    assert fr.capture("slo_critical", metrics=reg) is not None
    # global cap
    clk.t = 10.0
    assert fr.capture("spike", metrics=reg) is None
    assert len(fr.incidents) == 2


# ----------------------------------------------------------------------
# Perfetto counter-track validation
# ----------------------------------------------------------------------


def _counter_payload(events):
    meta = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
         "args": {"name": "slot0"}},
        {"ph": "M", "pid": 0, "tid": 1, "name": "thread_name",
         "args": {"name": "counters"}},
    ]
    span = [
        {"ph": "X", "pid": 0, "tid": 0, "ts": 0.0, "dur": 1.0,
         "name": "decode", "args": {}},
    ]
    return {"traceEvents": meta + span + events}


def test_validator_accepts_counter_series():
    rep = validate_trace(_counter_payload([
        {"ph": "C", "pid": 0, "tid": 1, "ts": 1.0, "name": "queue",
         "args": {"value": 3}},
        {"ph": "C", "pid": 0, "tid": 1, "ts": 2.0, "name": "queue",
         "args": {"value": 2}},
        {"ph": "C", "pid": 0, "tid": 1, "ts": 2.0, "name": "live",
         "args": {"value": 7.5}},
    ]))
    assert rep["counter_series"] == 2


def test_validator_rejects_bad_counter_events():
    with pytest.raises(TraceValidationError):
        validate_trace(_counter_payload([
            {"ph": "C", "pid": 0, "tid": 1, "ts": 1.0, "name": "q",
             "args": {"value": True}},  # bool is not a sample
        ]))
    with pytest.raises(TraceValidationError):
        validate_trace(_counter_payload([
            {"ph": "C", "pid": 0, "tid": 1, "ts": 1.0, "name": "q",
             "args": {}},
        ]))
    with pytest.raises(TraceValidationError):
        validate_trace(_counter_payload([
            {"ph": "C", "pid": 0, "tid": 1, "ts": 2.0, "name": "q",
             "args": {"value": 1}},
            {"ph": "C", "pid": 0, "tid": 1, "ts": 1.0, "name": "q",
             "args": {"value": 1}},
        ]))


# ----------------------------------------------------------------------
# HTTP endpoint (registry-only, then engine-wired below)
# ----------------------------------------------------------------------


def _get(url, timeout=10.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.read().decode()


def test_split_listen():
    assert split_listen("127.0.0.1:9090") == ("127.0.0.1", 9090)
    assert split_listen("[::1]:0") == ("[::1]", 0)
    with pytest.raises(ValueError):
        split_listen("9090")


def test_metrics_server_routes_and_errors():
    reg = MetricsRegistry()
    reg.counter("c_total", "help").inc(5)

    def boom():
        raise RuntimeError("nope")

    srv = MetricsServer(
        "127.0.0.1", 0,
        registry_fn=lambda: reg,
        vars_fn=lambda: {"enabled": True, "tok_s": 1.5},
        slo_fn=boom,
    )
    with srv:
        st, body = _get(srv.url + "/metrics")
        assert st == 200 and parse(body)["c_total"] == 5
        st, body = _get(srv.url + "/healthz")
        assert st == 200 and body == "ok\n"
        st, body = _get(srv.url + "/vars?span_s=5")
        assert st == 200 and json.loads(body)["tok_s"] == 1.5
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/slo")
        assert e.value.code == 500  # handler error -> 500, not a crash
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/nope")
        assert e.value.code == 404


# ----------------------------------------------------------------------
# Engine wiring
# ----------------------------------------------------------------------


def _prompts(n, rng=None, plen=12):
    rng = rng or np.random.default_rng(3)
    return [rng.integers(1, 127, plen).astype(np.int32) for _ in range(n)]


def test_monitoring_never_changes_streams_and_vars_agree():
    """The whole point of the off-hot-path design: monitor + SLO
    (shed disabled) emits bit-identical tokens to a bare engine, and a
    /vars window covering the run reproduces stats_summary()'s
    percentiles exactly."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    prompts = _prompts(3)
    streams, monitored = {}, None
    for on in (False, True):
        ecfg = EngineConfig(max_slots=2, max_len=64)
        if on:
            ecfg = EngineConfig(
                max_slots=2, max_len=64, monitor=300.0,
                slo=SloConfig(target=0.99, fast_window_s=30.0,
                              slow_window_s=300.0),
            )
        eng = Engine(cfg, mesh, engine_cfg=ecfg)
        for p in prompts:
            eng.submit(p, 8, schedule=ScheduleParams(deadline_s=120.0))
        fins = eng.drain(max_steps=300)
        streams[on] = [
            f.tokens.tolist() for f in sorted(fins, key=lambda f: f.uid)
        ]
        if on:
            monitored = eng
    assert streams[True] == streams[False]

    v = monitored.windowed_vars()
    assert v["enabled"] and v["covered_s"] >= 0.0
    s = monitored.stats_summary()
    # window spans the whole run -> exact agreement on raw-sample pcts
    assert v["token_latency_ms"]["p50_ms"] == pytest.approx(
        s["p50_token_latency_ms"], abs=1e-6
    )
    assert v["ttft_ms"]["p95_ms"] == pytest.approx(
        s["ttft_ms"]["p95_ms"], abs=1e-6
    )
    mem = v["memory"]
    assert mem["pool_pages"] > 0
    assert 0.0 <= mem["fragmentation"] <= 1.0
    slo = monitored.slo_state()
    assert slo["enabled"] and slo["state"] == OK  # generous deadlines
    # off engine exposes the disabled contract, not an error
    bare = Engine(
        cfg, mesh, engine_cfg=EngineConfig(max_slots=2, max_len=64)
    )
    assert bare.windowed_vars() == {"enabled": False}
    assert bare.slo_state() == {"enabled": False}
    assert bare.window_samples("repro_serve_ttft_seconds") == []


def test_slo_shed_rejects_lowest_priority_as_structured_results():
    """Impossible deadlines drive the monitor CRITICAL; with shed
    armed, queued lowest-priority requests come back as REJECT_SHED
    results (never silent drops) and high-priority work still
    finishes.  With shed off the same overload sheds nothing."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    for shed in (False, True):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=1,
                max_len=64,
                preemption=False,
                monitor=True,
                slo=SloConfig(
                    target=0.9,
                    fast_window_s=0.5,
                    slow_window_s=1.0,
                    warn_burn=2.0,
                    critical_burn=6.0,
                    shed=shed,
                    shed_max_per_tick=4,
                ),
            ),
        )
        prompts = _prompts(8, np.random.default_rng(11))
        # deadline'd stream that cannot possibly meet 1 ms end-to-end
        for p in prompts[:4]:
            eng.submit(
                p, 6,
                schedule=ScheduleParams(priority=1, deadline_s=1e-3),
            )
        # low-priority best-effort queue behind the single slot
        for p in prompts[4:]:
            eng.submit(p, 6, schedule=ScheduleParams(priority=0))
        fins = eng.drain(max_steps=3000)
        assert len(fins) == 8
        sheds = [f for f in fins if f.reject_reason == REJECT_SHED]
        if shed:
            assert sheds, "CRITICAL burn with shed=True must shed"
            assert all(f.finish_reason == "rejected" for f in sheds)
            # the low-priority class sheds first: every priority-0
            # request is gone, and any priority-1 shed (the queue ran
            # out of lower classes under sustained CRITICAL) happens
            # strictly after the last priority-0 one
            shed0 = [f for f in sheds if f.schedule.priority == 0]
            shed1 = [f for f in sheds if f.schedule.priority == 1]
            assert {f.uid for f in shed0} == {
                f.uid for f in fins if f.schedule.priority == 0
            }
            if shed1:
                assert min(f.finish_step for f in shed1) >= max(
                    f.finish_step for f in shed0
                )
            assert eng._slo_mon.transitions[CRITICAL] >= 1
            assert (
                eng.metrics["repro_serve_rejected_total"].get(
                    REJECT_SHED
                )
                == len(sheds)
            )
        else:
            assert not sheds
            assert all(f.finish_reason != "rejected" for f in fins)


def test_step_time_spike_produces_exactly_one_valid_bundle(tmp_path):
    """Inject a decode step-time spike after warmup: exactly one
    incident bundle, and its trace (with counter lanes) passes
    validate_trace_file."""
    cfg = _smoke_cfg()
    eng = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(
            max_slots=2, max_len=64, trace=True,
            flight_dir=str(tmp_path / "incidents"), spike_factor=8.0,
        ),
    )
    for p in _prompts(2):
        eng.submit(p, 6)
    eng.drain(max_steps=300)
    before = len(eng._flight.incidents)
    # warm the detector well past min_samples, then spike hard, twice
    # (cooldown + debounce must still yield exactly one bundle)
    for _ in range(32):
        eng._observe_step(0.01, 1, 0)
    eng._observe_step(5.0, 1, 0)
    eng._observe_step(5.0, 1, 0)
    bundles = eng._flight.incidents[before:]
    assert len(bundles) == 1 and "step_time_spike" in bundles[0]
    man = json.loads(
        (tmp_path / "incidents").joinpath(
            bundles[0].rsplit("/", 1)[-1], "manifest.json"
        ).read_text()
    )
    assert man["context"]["decode_step_s"] == 5.0
    assert man["config"]["max_slots"] == 2
    assert set(man["files"]) == {
        "manifest.json", "metrics.prom", "trace.json"
    }
    rep = validate_trace_file(
        str((tmp_path / "incidents").joinpath(
            bundles[0].rsplit("/", 1)[-1], "trace.json"
        ))
    )
    # the three per-step counter lanes ride along in the bundle
    assert rep["counter_series"] >= 3 and rep["spans"] > 0
    assert (
        eng.metrics["repro_flight_incidents_total"].get(
            "step_time_spike"
        )
        == 1
    )


def test_concurrent_scrape_vs_reset_stats():
    """A scrape racing reset_stats() and live stepping must always get
    a parseable exposition and consistent JSON — the registry swap is
    atomic, windows tick under the obs lock."""
    cfg = _smoke_cfg()
    eng = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(max_slots=2, max_len=64, monitor=True),
    )
    srv = attach(eng)
    stop = threading.Event()
    errors: list[str] = []
    scrapes = {"n": 0}

    def scraper():
        while not stop.is_set():
            try:
                _, body = _get(srv.url + "/metrics")
                parse(body)
                _, body = _get(srv.url + "/vars")
                assert json.loads(body)["enabled"] is True
                scrapes["n"] += 1
            except Exception as e:  # pragma: no cover - failure path
                errors.append(repr(e))
                return

    t = threading.Thread(target=scraper, daemon=True)
    t.start()
    try:
        rng = np.random.default_rng(5)
        for round_ in range(4):
            for p in _prompts(2, rng):
                eng.submit(p, 4)
            eng.drain(max_steps=300)
            eng.reset_stats()
    finally:
        stop.set()
        t.join(timeout=10.0)
        srv.stop()
    assert not errors, errors
    assert scrapes["n"] > 0
    # post-reset the window restarted: no stale samples survive
    assert eng.window_samples("repro_serve_ttft_seconds") == []
