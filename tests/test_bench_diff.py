"""scripts/bench_diff.py: trajectory-diff semantics.

The contract that matters for a stacked-PR repo: a scenario block that
is *new in the current* BENCH_serve.json (this PR grew the benchmark)
reports as "new" and never fails --strict, while a block that
*vanished* (a scenario silently stopped being measured) is flagged and
gates. Plain metric regressions keep flagging as before.
"""

import importlib.util
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
_spec = importlib.util.spec_from_file_location(
    "bench_diff", ROOT / "scripts" / "bench_diff.py"
)
bench_diff = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_diff)


def _payload(*, mesh: bool, server_tok_s: float = 10.0) -> dict:
    p = {
        "config": {"arch": "smoke"},
        "server": {"tok_s": server_tok_s},
        "engine_uniform": {
            "decode_tok_s": 100.0,
            "p95_token_latency_ms": 2.0,
        },
    }
    if mesh:
        p["mesh"] = {
            "streams_equal": True,
            "by_tp": {
                "1": {"decode_tok_s": 50.0},
                "8": {"decode_tok_s": 20.0},
            },
            "router": {"wall_tok_s": 30.0},
        }
    return p


def _run(tmp_path, monkeypatch, capsys, cur: dict, base: dict, *extra):
    c, b = tmp_path / "cur.json", tmp_path / "base.json"
    c.write_text(json.dumps(cur))
    b.write_text(json.dumps(base))
    monkeypatch.setattr(
        sys,
        "argv",
        ["bench_diff.py", "--current", str(c), "--baseline", str(b), *extra],
    )
    rc = bench_diff.main()
    return rc, capsys.readouterr().out


def test_new_trajectory_reports_new_and_passes_strict(
    tmp_path, monkeypatch, capsys
):
    rc, out = _run(
        tmp_path,
        monkeypatch,
        capsys,
        _payload(mesh=True),
        _payload(mesh=False),
        "--strict",
    )
    assert rc == 0
    assert "trajectory[mesh]" in out
    assert "new" in out
    assert "GONE" not in out
    # the mesh *metrics* are new too: reported, not flagged
    assert "mesh tp=8 decode tok/s" in out


def test_vanished_trajectory_flags_and_gates_strict(
    tmp_path, monkeypatch, capsys
):
    cur, base = _payload(mesh=False), _payload(mesh=True)
    rc, out = _run(tmp_path, monkeypatch, capsys, cur, base)
    assert rc == 0  # non-strict stays a report
    assert "GONE" in out and "trajectory[mesh]" in out
    rc, out = _run(tmp_path, monkeypatch, capsys, cur, base, "--strict")
    assert rc == 1


def test_metric_regression_still_flags(tmp_path, monkeypatch, capsys):
    rc, out = _run(
        tmp_path,
        monkeypatch,
        capsys,
        _payload(mesh=True, server_tok_s=4.0),
        _payload(mesh=True),
        "--strict",
    )
    assert rc == 1
    assert "REGRESSION" in out


def test_identical_payloads_clean(tmp_path, monkeypatch, capsys):
    rc, out = _run(
        tmp_path,
        monkeypatch,
        capsys,
        _payload(mesh=True),
        _payload(mesh=True),
        "--strict",
    )
    assert rc == 0
    assert "GONE" not in out and "REGRESSION" not in out


# ----------------------------------------------------------------------
# missing / unparsable inputs: informational by default, fatal --strict
# ----------------------------------------------------------------------


def _run_raw(tmp_path, monkeypatch, capsys, *argv):
    monkeypatch.setattr(sys, "argv", ["bench_diff.py", *argv])
    rc = bench_diff.main()
    captured = capsys.readouterr()
    return rc, captured.out + captured.err


def test_missing_current_fails_strict(tmp_path, monkeypatch, capsys):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_payload(mesh=True)))
    missing = tmp_path / "nope.json"
    rc, out = _run_raw(
        tmp_path, monkeypatch, capsys,
        "--current", str(missing), "--baseline", str(base),
    )
    assert rc == 0 and "cannot read" in out  # tier-1 mode stays a report
    rc, out = _run_raw(
        tmp_path, monkeypatch, capsys,
        "--current", str(missing), "--baseline", str(base), "--strict",
    )
    assert rc == 1 and "cannot read" in out


def test_unparsable_current_fails_strict_without_traceback(
    tmp_path, monkeypatch, capsys
):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_payload(mesh=True)))
    broken = tmp_path / "broken.json"
    broken.write_text("{ not json")
    # previously an unhandled json.JSONDecodeError traceback
    rc, out = _run_raw(
        tmp_path, monkeypatch, capsys,
        "--current", str(broken), "--baseline", str(base), "--strict",
    )
    assert rc == 1 and "cannot read" in out
    rc, _ = _run_raw(
        tmp_path, monkeypatch, capsys,
        "--current", str(broken), "--baseline", str(base),
    )
    assert rc == 0


def test_unreadable_explicit_baseline_fails_strict(
    tmp_path, monkeypatch, capsys
):
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(_payload(mesh=True)))
    broken = tmp_path / "base.json"
    broken.write_text("]]")
    rc, out = _run_raw(
        tmp_path, monkeypatch, capsys,
        "--current", str(cur), "--baseline", str(broken), "--strict",
    )
    assert rc == 1 and "cannot read baseline" in out
    rc, _ = _run_raw(
        tmp_path, monkeypatch, capsys,
        "--current", str(cur), "--baseline", str(broken),
    )
    assert rc == 0
