"""Pixelfly linear layer: parameterization W = gamma*B + (1-gamma)*UV^T."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import budget as budget_lib
from repro.core.pixelfly import LinearSpec, apply_linear, init_linear, param_count
from repro.kernels import ref


def test_dense_vs_sparse_param_savings():
    d = LinearSpec.dense(1024, 1024, dtype=jnp.float32)
    s = LinearSpec.pixelfly(1024, 1024, 0.2, block=128, dtype=jnp.float32)
    assert param_count(s) < 0.35 * param_count(d)


def test_apply_matches_manual():
    spec = LinearSpec.pixelfly(256, 256, 0.5, block=64, dtype=jnp.float32)
    params = init_linear(jax.random.PRNGKey(0), spec)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256)), jnp.float32)
    y = apply_linear(spec, params, x)
    pat = spec.pattern()
    ys = ref.bsr_matmul_gather(x, params["blocks"], jnp.asarray(pat.cols))
    yl = (x @ params["U"]) @ params["V"].T
    g = float(params["gamma"])
    np.testing.assert_allclose(
        np.asarray(y), g * np.asarray(ys) + (1 - g) * np.asarray(yl),
        rtol=1e-4, atol=1e-4,
    )


def test_gamma_gradient():
    spec = LinearSpec.pixelfly(128, 128, 0.5, block=64, dtype=jnp.float32)
    params = init_linear(jax.random.PRNGKey(0), spec)
    x = jnp.ones((2, 128), jnp.float32)

    def f(p):
        return apply_linear(spec, p, x).sum()

    g = jax.grad(f)(params)
    assert abs(float(g["gamma"])) > 0  # gamma is learnable end-to-end


def test_bias():
    spec = LinearSpec.pixelfly(128, 128, 0.5, block=64, use_bias=True, dtype=jnp.float32)
    params = init_linear(jax.random.PRNGKey(0), spec)
    assert "b" in params
    y0 = apply_linear(spec, params, jnp.zeros((1, 128), jnp.float32))
    params2 = dict(params, b=params["b"] + 1.0)
    y1 = apply_linear(spec, params2, jnp.zeros((1, 128), jnp.float32))
    np.testing.assert_allclose(np.asarray(y1 - y0), 1.0, rtol=1e-5)


def test_output_variance_reasonable():
    """Init scaling: output std within ~3x of dense at same width."""
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (512, 1024), jnp.float32)
    sd = LinearSpec.dense(1024, 1024, dtype=jnp.float32)
    ss = LinearSpec.pixelfly(1024, 1024, 0.25, block=128, dtype=jnp.float32)
    yd = apply_linear(sd, init_linear(rng, sd), x)
    ys = apply_linear(ss, init_linear(rng, ss), x)
    r = float(ys.std() / yd.std())
    assert 0.3 < r < 3.0, r


def test_budget_split_respects_density():
    for density in [0.1, 0.2, 0.4]:
        rank, stride = budget_lib.split_sparse_lowrank(4096, 4096, density, block=128)
        total = rank * 8192 + (1 + len([s for s in [1,2,4,8,16,32] if s < stride])) * 0  # not exact; just sanity below
        spec = LinearSpec.pixelfly(4096, 4096, density, block=128)
        assert param_count(spec) <= density * 4096 * 4096 * 1.35 + 128 * 8192


def test_closed_form_budget_allocation():
    d_a, d_m = budget_lib.solve_two_type_closed_form(512, 768, 0.25 * 12 * 768 * 768)
    assert 0 <= d_a <= 1 and 0 <= d_m <= 1
    assert d_a > 0 or d_m > 0
