"""Tier-1 smoke: every module under examples/ imports and dry-runs.

Examples are the repo's public API surface — they rot silently when an
Engine/Trainer signature changes, because nothing imported them. Each
example exposes ``main(argv)`` with a ``--smoke`` flag that shrinks the
model and workload to seconds (``quickstart.py`` is script-style: its
import *is* the dry-run). A new example is picked up automatically by
the glob — and must either run at import or accept ``--smoke``.
"""

import importlib.util
import pathlib
import sys

import pytest

EXAMPLES_DIR = pathlib.Path(__file__).resolve().parent.parent / "examples"
EXAMPLES = sorted(EXAMPLES_DIR.glob("*.py"))


def _load(path: pathlib.Path):
    name = f"examples_smoke_{path.stem}"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    try:
        spec.loader.exec_module(mod)
    finally:
        sys.modules.pop(name, None)
    return mod


def test_examples_dir_is_covered():
    assert len(EXAMPLES) >= 4  # the glob found the real directory


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.stem)
def test_example_imports_and_dry_runs(path, capsys):
    mod = _load(path)  # import-time failure fails here
    if hasattr(mod, "main"):
        mod.main(["--smoke"])  # every main() must take argv + --smoke
        assert capsys.readouterr().out.strip()  # it printed something
    # script-style examples (quickstart) already ran at import
