"""Mesh-sharded serving: paged-pool sharding rules (always run) and
tensor-parallel / data-parallel stream parity on a simulated 8-device
mesh (run under ``JAX_PLATFORMS=cpu`` with
``--xla_force_host_platform_device_count=8`` — scripts/tier1.sh's mesh
leg; skipped on the default single-device test process).

The parity standard is the engine's own: identical *token streams*
(greedy argmax and seeded sampling), not bitwise logits — TP all-reduce
changes fp summation order, and the sampler's noise is keyed on
(request seed, sample index) only, so streams are device-layout
invariant.
"""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding
from repro.serving import Engine, EngineConfig
from repro.serving.router import ReplicaRouter
from repro.serving.sampling import SamplingParams

requires_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs a simulated 8-device mesh "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _abstract_mesh(data: int, model: int):
    try:
        return jax.sharding.AbstractMesh((data, model), ("data", "model"))
    except TypeError:  # jax 0.4.x: tuple of (name, size) pairs
        return jax.sharding.AbstractMesh(
            (("data", data), ("model", model))
        )


def _sub_mesh(k: int) -> Mesh:
    sub = np.asarray(jax.devices()[:k]).reshape(1, k)
    return Mesh(sub, ("data", "model"))


# ----------------------------------------------------------------------
# Sharding rules (no devices needed — run in the default tier-1 pass)
# ----------------------------------------------------------------------


def test_paged_cache_specs_shard_kv_heads_when_divisible():
    st = sharding.Strategy(_abstract_mesh(1, 2), "tp")
    pools = [
        {
            "k": jax.ShapeDtypeStruct((3, 9, 64, 4, 64), np.float32),
            "v": jax.ShapeDtypeStruct((3, 9, 64, 4, 64), np.float32),
        }
    ]
    specs = sharding.cache_specs(st, pools, layout="paged")
    assert specs[0]["k"] == P(None, None, None, "model", None)
    assert specs[0]["v"] == P(None, None, None, "model", None)


def test_paged_cache_specs_head_dim_fallback_and_replication():
    st = sharding.Strategy(_abstract_mesh(1, 8), "tp")
    # kv_heads=2 does not divide tp=8; head_dim=64 does
    pool = {"k": jax.ShapeDtypeStruct((2, 9, 64, 2, 64), np.float32)}
    specs = sharding.cache_specs(st, [pool], layout="paged")
    assert specs[0]["k"] == P(None, None, None, None, "model")
    # neither head axis divisible -> fully replicated (never the page axes)
    pool = {"k": jax.ShapeDtypeStruct((2, 16, 8, 3, 5), np.float32)}
    specs = sharding.cache_specs(st, [pool], layout="paged")
    assert specs[0]["k"] == P(None, None, None, None, None)


def test_paged_cache_specs_fsdp_replicates():
    # fsdp strategy has no model axis: pools replicate, page axes and
    # head axes alike (DP is replica routing, not a sharded pool)
    st = sharding.Strategy(_abstract_mesh(4, 2), "fsdp")
    pool = {"k": jax.ShapeDtypeStruct((2, 9, 64, 4, 64), np.float32)}
    specs = sharding.cache_specs(st, [pool], layout="paged")
    assert specs[0]["k"] == P(None, None, None, None, None)


def test_cache_specs_decode_layout_unchanged():
    # the contiguous (count, B, S, ...) decode layout keeps its rule
    st = sharding.Strategy(_abstract_mesh(2, 2), "tp")
    caches = [{"k": jax.ShapeDtypeStruct((2, 4, 128, 4, 64), np.float32)}]
    specs = sharding.cache_specs(st, caches)
    assert specs[0]["k"][1] is not None  # batch dim sharded over data


def test_unknown_cache_layout_raises():
    st = sharding.Strategy(_abstract_mesh(1, 2), "tp")
    with pytest.raises(ValueError):
        sharding.cache_specs(st, [], layout="nope")


# ----------------------------------------------------------------------
# Simulated-mesh parity (8 forced host devices)
# ----------------------------------------------------------------------


def _cfg():
    return registry.get_smoke("qwen3-1.7b", sparse=True).replace(
        num_layers=2, vocab_size=256
    )


def _prompts(n=4):
    rng = np.random.default_rng(0)
    return [
        rng.integers(1, 250, size=ln).astype(np.int32)
        for ln in (9, 17, 5, 12)[:n]
    ]


def _run_engine(tp: int, sampled: bool, cfg, params=None):
    eng = Engine(
        cfg,
        _sub_mesh(tp),
        engine_cfg=EngineConfig(max_slots=4, max_len=64, prefix_cache=True),
        strategy="tp",
        seed=0,
        params=params,
    )
    for i, p in enumerate(_prompts()):
        sp = (
            SamplingParams(temperature=0.8, top_k=40, seed=100 + i)
            if sampled
            else None
        )
        eng.submit(p, 12, sampling=sp)
    fins = eng.drain(max_steps=80)
    return {f.uid: f.tokens.tolist() for f in fins}, eng


@requires_mesh
@pytest.mark.parametrize("tp", [2, 8])
@pytest.mark.parametrize("sampled", [False, True])
def test_tp_streams_bit_identical_to_single_device(tp, sampled):
    cfg = _cfg()
    base, _ = _run_engine(1, sampled, cfg)
    got, eng = _run_engine(tp, sampled, cfg)
    assert eng.paged_impl == "gather"  # pallas has no partitioning rule
    assert got == base


@requires_mesh
def test_tp_pool_buffers_actually_sharded():
    cfg = _cfg()
    _, eng = _run_engine(2, False, cfg)
    spec = tuple(eng.kv.buffers[0]["k"].sharding.spec)
    spec = spec + (None,) * (5 - len(spec))  # jax trims trailing Nones
    # smoke kv_heads=2 divides tp=2: classic head sharding on axis 3
    assert spec == (None, None, None, "model", None)
    assert eng.kv.shardings is not None


@requires_mesh
@pytest.mark.parametrize("tp", [1, 2])
def test_replica_router_streams_match_single_engine(tp):
    cfg = _cfg()
    base, _ = _run_engine(1, True, cfg)
    router = ReplicaRouter(
        cfg,
        replicas=2,
        tp=tp,
        engine_cfg=EngineConfig(max_slots=4, max_len=64, prefix_cache=True),
        seed=0,
    )
    uids = []
    for i, p in enumerate(_prompts()):
        uids.append(
            router.submit(
                p,
                12,
                sampling=SamplingParams(
                    temperature=0.8, top_k=40, seed=100 + i
                ),
            )
        )
    fins = {f.uid: f.tokens.tolist() for f in router.drain(max_steps=200)}
    # same submit order -> same router uids as the single engine's
    assert fins == base
    # traffic actually spread over both replicas
    assert all(n == 0 for n in router._outstanding)
    assert len(router.engines) == 2
    assert sum(e.stats.finished for e in router.engines) == len(uids)


@requires_mesh
def test_router_rejects_when_devices_insufficient():
    with pytest.raises(ValueError):
        ReplicaRouter(_cfg(), replicas=16, tp=8)


@requires_mesh
def test_router_zero_traffic_replica_observability():
    """A replica that never saw a request must not poison fleet
    aggregation: merged metrics stay parseable, fleet percentiles come
    from the replicas that do have samples, and the fleet /slo state
    is well-defined."""
    from repro.obs import SloConfig
    from repro.obs.prom import parse, render

    cfg = _cfg()
    router = ReplicaRouter(
        cfg,
        replicas=2,
        engine_cfg=EngineConfig(
            max_slots=4,
            max_len=64,
            monitor=60.0,
            slo=SloConfig(
                target=0.99, fast_window_s=10.0, slow_window_s=60.0
            ),
        ),
        seed=0,
    )
    # one request -> least-loaded routing sends it to replica 0 only
    router.submit(_prompts(1)[0], 8)
    fins = router.drain(max_steps=80)
    assert len(fins) == 1
    assert router.engines[0].stats.finished == 1
    assert router.engines[1].stats.finished == 0

    flat = parse(render(router.merged_metrics()))
    assert flat["repro_serve_requests_finished_total"] == 1

    s = router.stats_summary()
    assert s["requests_finished"] == 1
    assert [r["requests_finished"] for r in s["per_replica"]] == [1, 0]
    assert s["per_replica"][1]["p50_token_latency_ms"] == 0.0

    v = router.windowed_vars()
    assert v["enabled"] and v["replicas"] == 2
    # fleet percentile == replica 0's (replica 1 contributes nothing,
    # and an average-of-averages would halve it)
    v0 = router.engines[0].windowed_vars()
    assert v["token_latency_ms"] == v0["token_latency_ms"]
    assert v["queue_depth"] == 0 and v["running_slots"] == 0

    slo = router.slo_state()
    assert slo["enabled"] and slo["state"] == "OK"
    assert len(slo["per_replica"]) == 2
