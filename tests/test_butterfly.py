"""Properties of the flat block butterfly pattern (paper Defs 3.1-3.4)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import butterfly as bf


def test_log2_int():
    assert bf.log2_int(1) == 0
    assert bf.log2_int(64) == 6
    with pytest.raises(ValueError):
        bf.log2_int(12)


def test_strides():
    assert bf.flat_butterfly_strides(1) == []
    assert bf.flat_butterfly_strides(2) == [1]
    assert bf.flat_butterfly_strides(16) == [1, 2, 4, 8]


@given(
    nb=st.sampled_from([1, 2, 4, 8, 16, 32]),
    ks=st.integers(0, 5),
)
@settings(max_examples=50, deadline=None)
def test_square_cols_structure(nb, ks):
    k = min(1 << ks, nb)
    cols = bf.flat_butterfly_cols(nb, nb, k)
    assert cols.shape == (nb, 1 + len(bf.flat_butterfly_strides(k)))
    for i in range(nb):
        assert cols[i, 0] == i  # diagonal slot
        for t, s in enumerate(bf.flat_butterfly_strides(k)):
            assert cols[i, 1 + t] == i ^ s  # XOR stride
        assert (cols[i] < nb).all() and (cols[i] >= 0).all()


@given(
    nbo=st.integers(1, 24),
    nbi=st.integers(1, 24),
    ks=st.integers(0, 4),
)
@settings(max_examples=60, deadline=None)
def test_rectangular_cols_in_range(nbo, nbi, ks):
    cols = bf.flat_butterfly_cols(nbo, nbi, 1 << ks)
    assert (cols >= 0).all() and (cols < nbi).all()


def test_pattern_symmetry_square():
    """Square flat butterfly pattern is symmetric (i XOR s is an involution)."""
    p = bf.make_pattern(1024, 1024, block=128, max_stride=8)
    m = p.dense_mask()
    assert np.array_equal(m, m.T)


def test_nnz_formula():
    p = bf.make_pattern(2048, 2048, block=128, max_stride=16)
    r = 1 + 4
    assert p.r == r
    assert p.nnz == (2048 // 128) * r * 128 * 128
    assert abs(p.density - r * 128 / 2048) < 1e-9


def test_block_cover_and_density():
    rng = np.random.default_rng(0)
    mask = (rng.random((64, 64)) < 0.02).astype(np.float32)
    cover = bf.block_cover(mask, 8, 8)
    # cover >= mask, block-aligned
    assert (cover >= mask).all()
    c = cover.reshape(8, 8, 8, 8)
    per_block = c.transpose(0, 2, 1, 3).reshape(64, 64)
    blocks = cover.reshape(8, 8, 8, 8).any(axis=(1, 3))
    assert ((cover.reshape(8, 8, 8, 8).sum(axis=(1, 3)) % 64) == 0).all()
    # density of block cover >= element density (Table 7 phenomenon)
    assert bf.block_cover_density(mask, 8) >= mask.mean()


@given(b=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=10, deadline=None)
def test_butterfly_pattern_block_aligned(b):
    """The flat block butterfly mask is its own (b, b)-block cover —
    the hardware-alignment property the paper is built on."""
    p = bf.make_pattern(32 * b, 32 * b, block=b, max_stride=8)
    m = p.dense_mask()
    assert np.array_equal(m, bf.block_cover(m, b, b))


def test_block_butterfly_factor_matrix():
    rng = np.random.default_rng(0)
    m = bf.butterfly_factor_matrix(8, 4, rng, block=2)
    # nonzero blocks exactly at (i, i) and (i, i XOR 2) within 4-groups
    nz = (np.abs(m.reshape(8, 2, 8, 2)).sum(axis=(1, 3)) > 0)
    for i in range(8):
        base = (i // 4) * 4
        expect = {i, base + ((i - base) ^ 2)}
        assert set(np.nonzero(nz[i])[0]) == expect


def test_max_stride_for_density_monotone():
    prev = 0
    for d in [0.05, 0.1, 0.2, 0.4, 0.8]:
        k = bf.max_stride_for_density(4096, 128, d)
        assert k >= prev
        prev = k


def test_density_never_exceeded():
    for d in [0.05, 0.1, 0.2, 0.5]:
        p = bf.make_pattern(4096, 4096, block=128, density=d)
        assert p.density <= d + 128 / 4096 + 1e-9
