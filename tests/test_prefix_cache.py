"""Prefix-cache subsystem: refcounted page sharing, copy-on-write,
radix-tree matching/eviction, cache-aware partial prefill, and the
engine-level guarantee that the cache is a pure optimization (identical
token streams on vs off, greedy and seeded-sampled)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import attn_pattern as ap
from repro.launch.mesh import make_local_mesh
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving import (
    Engine,
    EngineConfig,
    PagedKVCache,
    PrefixCache,
    SamplingParams,
    Scheduler,
)
from repro.serving.request import Request


def _smoke_cfg(**kw):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=2, vocab_size=128, **kw
    )


def _tiny_cfg(page=4):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=1, num_heads=2, num_kv_heads=1, head_dim=8,
        attn_block=page,
    )


# ----------------------------------------------------------------------
# Refcounted allocator: COW + atomic alloc_upto (no model math)
# ----------------------------------------------------------------------


def test_cow_page_copies_device_content_and_remaps():
    cfg = _tiny_cfg()
    kv = PagedKVCache(cfg, max_slots=2, max_len=4 * cfg.attn_block)
    kv.alloc_upto(0, kv.page - 1)
    src = int(kv.page_table[0, 0])
    # stamp recognizable content into the shared page
    for pool in kv.buffers:
        pool["k"] = pool["k"].at[:, src].set(7.5)
        pool["v"] = pool["v"].at[:, src].set(-3.25)
    kv.incref(src)  # a second reference (as if mapped into another slot)
    free_before = kv.free_pages
    dst = kv.cow_page(0, 0)
    assert dst != src
    assert kv.page_table[0, 0] == dst
    assert kv.refcount(dst) == 1 and kv.refcount(src) == 1
    assert kv.free_pages == free_before - 1
    for pool in kv.buffers:
        np.testing.assert_array_equal(
            np.asarray(pool["k"][:, dst]), np.asarray(pool["k"][:, src])
        )
        assert (np.asarray(pool["v"][:, dst]) == -3.25).all()
    kv.unpin(src)  # phantom holder drops its pin -> parked
    assert kv.is_cached(src)


def test_alloc_upto_atomic_rollback_on_exhaustion():
    """Regression: pool exhaustion mid-growth used to leave the slot
    half-grown (pages allocated, then a raise) — the rollback must
    restore _owned/page_table/free list exactly."""
    cfg = _tiny_cfg()
    page = cfg.attn_block
    kv = PagedKVCache(cfg, max_slots=2, max_len=4 * page, n_pages=6)
    kv.alloc_upto(0, 3 * page - 1)  # 3 of 5 usable pages
    # slot 1 wants 3 pages; only 2 are free -> must fail WITHOUT
    # retaining the 2 it could have grabbed
    with pytest.raises(RuntimeError):
        kv.alloc_upto(1, 3 * page - 1)
    assert kv.pages_owned(1) == 0
    assert (kv.page_table[1] == 0).all()
    assert kv.free_pages == 2
    # partially-grown slot: rollback only the new pages, keep the old
    kv.alloc_upto(1, page - 1)
    assert kv.pages_owned(1) == 1
    first = int(kv.page_table[1, 0])
    with pytest.raises(RuntimeError):
        kv.alloc_upto(1, 4 * page - 1)
    assert kv.pages_owned(1) == 1 and kv.page_table[1, 0] == first
    assert kv.free_pages == 1
    # and the failed grow didn't corrupt refcounts
    assert kv.refcount(first) == 1
    kv.free_slot(0), kv.free_slot(1)
    assert kv.free_pages == kv.n_pages - 1


# ----------------------------------------------------------------------
# Radix tree: matching, the one-token cap, LRU leaf eviction
# ----------------------------------------------------------------------


def test_radix_match_insert_and_suffix_cap():
    cfg = _tiny_cfg()
    page = cfg.attn_block
    kv = PagedKVCache(cfg, max_slots=2, max_len=4 * page)
    pc = PrefixCache(kv)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 50, 3 * page + 2).astype(np.int32)

    assert pc.match(prompt) == []  # empty tree
    kv.alloc_upto(0, prompt.size - 1)
    pc.insert(prompt, kv.page_table[0])
    assert pc.nodes == 3  # full blocks only; the partial tail is private

    # full three-block hit (suffix of 2 tokens remains)
    pages = pc.match(prompt)
    assert pages == [int(kv.page_table[0, i]) for i in range(3)]
    # page-multiple prompt: the cap drops the last block so >= 1 token
    # of suffix is always left to prefill (its logits emit token 0)
    assert len(pc.match(prompt[: 3 * page])) == 2
    assert len(pc.match(prompt[: page + 1])) == 1
    # diverging block: no hit beyond the shared prefix
    other = prompt.copy()
    other[page + 3] += 1
    assert len(pc.match(other)) == 1


def test_radix_lru_evicts_leaves_first():
    cfg = _tiny_cfg()
    page = cfg.attn_block
    kv = PagedKVCache(cfg, max_slots=3, max_len=4 * page)
    pc = PrefixCache(kv)
    rng = np.random.default_rng(1)
    a = rng.integers(0, 50, 2 * page + 1).astype(np.int32)
    b = rng.integers(50, 100, 2 * page + 1).astype(np.int32)
    for slot, prompt in ((0, a), (1, b)):
        kv.alloc_upto(slot, prompt.size - 1)
        pc.insert(prompt, kv.page_table[slot])
        kv.free_slot(slot, keep=pc.page_in_tree)
    assert kv.cached_pages == 4 and kv.free_pages == kv.n_pages - 5
    a_pages = pc.match(a)  # refresh A's ticks: B is now LRU
    pc.match(a)

    assert pc.ensure_free(kv.free_pages + 2)
    # B's chain went (leaf before its parent — never orphan a child)
    assert pc.match(b) == []
    assert pc.match(a) == a_pages  # A survived
    # evicting the rest takes A too; further asks are refused, not stuck
    assert pc.ensure_free(kv.free_pages + 2)
    assert not pc.ensure_free(kv.free_pages + 1)
    assert kv.cached_pages == 0


# ----------------------------------------------------------------------
# Partial prefill vs the full-prefill oracle
# ----------------------------------------------------------------------


@pytest.mark.parametrize("sparse", [False, True])
def test_partial_prefill_matches_full_prefill(sparse):
    """Suffix-only prefill over shared prefix pages must reproduce the
    full prefill bit-for-bit in what matters: last-token logits and the
    suffix K/V pages it scatters."""
    cfg = _smoke_cfg(sparse_attention=sparse)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    page = cfg.attn_block
    rng = np.random.default_rng(0)
    plen = 2 * page + 17
    prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    s_full = 4 * page

    kv = PagedKVCache(cfg, max_slots=2, max_len=4 * page)
    kv.alloc_upto(0, plen - 1)
    tokens = np.zeros((1, s_full), np.int32)
    tokens[0, :plen] = prompt
    ref_logits, kv.buffers = T.prefill_paged(
        cfg, params, jnp.asarray(tokens), jnp.asarray([plen], np.int32),
        kv.buffers, jnp.asarray(kv.bucket_row(0, plen, 4))[None],
    )

    # slot 1: adopt slot 0's two full pages, prefill only the suffix
    npre = 2
    pre = [int(kv.page_table[0, i]) for i in range(npre)]
    for p in pre:
        kv.incref(p)
    kv.adopt(1, pre)
    kv.alloc_upto(1, plen - 1)
    suf_len = plen - npre * page
    suf_tokens = np.zeros((1, page), np.int32)
    suf_tokens[0, :suf_len] = prompt[npre * page :]
    got_logits, kv.buffers = T.prefill_paged(
        cfg, params, jnp.asarray(suf_tokens),
        jnp.asarray([suf_len], np.int32), kv.buffers,
        jnp.asarray(kv.suffix_row(1, npre, plen, 1))[None],
        prefix_rows=jnp.asarray(np.asarray(pre, np.int32))[None],
        prefix_lens=jnp.asarray([npre * page], np.int32),
    )
    np.testing.assert_allclose(
        np.asarray(got_logits), np.asarray(ref_logits), rtol=1e-5, atol=1e-5
    )
    for pool in kv.buffers:
        for name in ("k", "v"):
            np.testing.assert_allclose(
                np.asarray(pool[name][:, kv.page_table[1, npre]]),
                np.asarray(pool[name][:, kv.page_table[0, npre]]),
                rtol=1e-5,
                atol=1e-5,
            )


@pytest.mark.parametrize(
    "local,glob,stride", [(2, 1, 0), (1, 0, 0), (1, 2, 4), (3, 1, 2)]
)
def test_elementwise_pixelfly_mask_matches_reference(local, glob, stride):
    """The partial-prefill path rebuilds the pixelfly block mask
    elementwise from absolute positions; on power-of-two block grids it
    must equal the stretched-grid reference exactly (the full-prefill
    schedule), or cached prefixes would attend differently."""
    block = 4
    for nb in (1, 2, 4, 8, 16):
        ref = ap.pixelfly_attention_block_mask(
            nb * block,
            nb * block,
            ap.AttentionPatternConfig(
                block=block,
                local_blocks=local,
                max_stride=stride,
                global_blocks=glob,
            ),
            causal=True,
        )
        qb = np.arange(nb)
        # last row of each q block vs first column of each k block:
        # kpos <= qpos exactly when kb <= qb, isolating block visibility
        got = np.asarray(
            L._pixelfly_visible(
                jnp.asarray(qb[:, None] * block + block - 1),
                jnp.asarray(qb[None, :] * block),
                block=block,
                local_blocks=local,
                global_blocks=glob,
                max_stride=stride,
            )
        )
        np.testing.assert_array_equal(got, ref)


# ----------------------------------------------------------------------
# Engine: the cache is a pure optimization
# ----------------------------------------------------------------------


def _shared_prefix_trace(cfg, rng, n, sys_pages=(1, 2)):
    """Requests drawing from a couple of shared system prompts plus a
    random tail; ~1/4 share nothing at all."""
    page = cfg.attn_block
    sys_prompts = [
        rng.integers(0, cfg.vocab_size, k * page).astype(np.int32)
        for k in sys_pages
    ]
    out = []
    for _ in range(n):
        tail = rng.integers(
            0, cfg.vocab_size, int(rng.integers(3, page))
        ).astype(np.int32)
        r = int(rng.integers(0, len(sys_prompts) + 1))
        prompt = (
            tail
            if r == len(sys_prompts)
            else np.concatenate([sys_prompts[r], tail])
        )
        out.append((prompt, int(rng.integers(2, 6))))
    return out


@pytest.mark.parametrize("sampled", [False, True])
def test_engine_prefix_on_off_identical_streams(sampled):
    """Differential parity: prefix cache on vs off over a randomized
    shared-prefix trace produces bit-identical token streams, greedy and
    seeded-sampled (sampling determinism survives partial prefill: the
    noise stream keys on (seed, sample_idx) only, and the presence
    buffer is seeded from the whole prompt, cached prefix included)."""
    cfg = _smoke_cfg(sparse_attention=True)
    mesh = make_local_mesh()
    rng = np.random.default_rng(23)
    trace = _shared_prefix_trace(cfg, rng, 10)
    page = cfg.attn_block

    params = None
    streams, hit_stats = {}, {}
    for on in (False, True):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=3, max_len=4 * page, prefix_cache=on
            ),
            params=params,
        )
        params = eng.params
        srng = np.random.default_rng(7)  # same interleaving both runs
        out, pending = {}, list(trace)
        k = 0
        while pending or not eng.scheduler.idle:
            burst = int(srng.integers(1, 4))
            for prompt, gen in pending[:burst]:
                sp = (
                    SamplingParams(
                        temperature=0.9, top_k=25, top_p=0.9, seed=1000 + k
                    )
                    if sampled and k % 2  # mix plain + sampled traffic
                    else None
                )
                eng.submit(prompt, gen, sampling=sp)
                k += 1
            pending = pending[burst:]
            for f in eng.step():
                out[f.uid] = (f.tokens.tolist(), f.prefix_hit_tokens)
        streams[on] = out
        hit_stats[on] = eng.stats_summary()["prefix_cache"]
        # page conservation at idle: everything not parked is free
        assert eng.kv.free_pages + eng.kv.cached_pages == eng.kv.n_pages - 1
        if on:
            assert eng.kv.cached_pages > 0

    assert streams[True].keys() == streams[False].keys()
    for uid in streams[False]:
        assert streams[True][uid][0] == streams[False][uid][0]
    # the cache actually did something: hits happened, prefill shrank
    assert hit_stats[True]["hit_tokens"] > 0
    assert any(hit for _, hit in streams[True].values())
    assert all(hit == 0 for _, hit in streams[False].values())


def test_engine_prefix_hit_prefills_only_suffix():
    """A hit admission must issue the *partial* prefill program (suffix
    bucket + prefix rows), count only suffix tokens as prefilled, and
    report the hit on the finished request."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=4 * page,
                                prefix_cache=True),
    )
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, cfg.vocab_size, 2 * page).astype(np.int32)
    tails = [
        rng.integers(0, cfg.vocab_size, 9).astype(np.int32) for _ in range(2)
    ]

    eng.submit(np.concatenate([sys_prompt, tails[0]]), 2)
    f0 = eng.drain(max_steps=20)[0]
    assert f0.prefix_hit_tokens == 0

    calls = []
    orig = eng._prefill_pre
    def counting(*a):
        calls.append((tuple(a[1].shape), tuple(a[5].shape)))
        return orig(*a)
    eng._prefill_pre = counting

    eng.reset_stats()
    eng.submit(np.concatenate([sys_prompt, tails[1]]), 2)
    f1 = eng.drain(max_steps=20)[0]
    assert f1.prefix_hit_tokens == 2 * page
    # one partial-prefill call: (N=1, S=1 page suffix), 2 prefix pages
    assert calls == [((1, page), (1, 2))]
    s = eng.stats_summary()
    assert s["prefill_tokens"] == 9  # the suffix, not the whole prompt
    assert s["prefix_cache"]["hit_tokens"] == 2 * page
    assert s["prefix_cache"]["hit_rate"] == pytest.approx(
        2 * page / (2 * page + 9), abs=1e-3
    )


def test_engine_decode_pages_indexed_for_multi_turn_chat():
    """A finished sequence's decode-written pages are indexed into the
    radix tree, so a chat turn-2 prompt (turn-1 prompt + answer + new
    user text) hits pages that were never prefilled as prompt content."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block
    eng = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=6 * page,
                                prefix_cache=True),
    )
    rng = np.random.default_rng(5)
    user1 = rng.integers(0, cfg.vocab_size, page).astype(np.int32)
    eng.submit(user1, page + 1)
    f1 = eng.drain(max_steps=200)[0]
    assert len(f1.tokens) == page + 1
    # written history = prompt + generated[:-1] (the last token was
    # never written back) spans 2 full pages; the prompt page was
    # already indexed at admission, so finish indexes 1 *new* decode page
    assert eng.stats_summary()["prefix_cache"]["decode_indexed_pages"] == 1

    prompt2 = np.concatenate(
        [user1, f1.tokens, rng.integers(0, cfg.vocab_size, page
                                        ).astype(np.int32)]
    )
    eng.submit(prompt2, 4)
    f2 = eng.drain(max_steps=40)[0]
    # both indexed pages hit even though one was decode-written
    assert f2.prefix_hit_tokens == 2 * page
    s = eng.stats_summary()
    assert s["prefix_cache"]["hit_pages"] == 2


def test_engine_prefix_eviction_never_blocks_admission():
    """With a pool sized so parked pages must be reclaimed, admission
    evicts LRU cached pages instead of failing — the cache is strictly
    opportunistic."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block
    # 2 slots x 2 pages worst case = 4 usable pages (5 with trash)
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=2 * page,
                                prefix_cache=True),
    )
    rng = np.random.default_rng(11)
    # two disjoint 1-page prompts -> 2+ parked pages after they finish
    for _ in range(2):
        eng.submit(
            rng.integers(0, cfg.vocab_size, page + 3).astype(np.int32), 2
        )
    eng.drain(max_steps=30)
    assert eng.kv.cached_pages >= 2
    # now a wave needing the whole pool: parked pages must be evicted
    for _ in range(2):
        eng.submit(
            rng.integers(0, cfg.vocab_size, 2 * page - 2).astype(np.int32), 2
        )
    fins = eng.drain(max_steps=40)
    assert len(fins) == 2
    assert eng._prefix.stats.evicted_pages > 0
    assert eng.kv.free_pages + eng.kv.cached_pages == eng.kv.n_pages - 1


def test_engine_cow_guard_preserves_stream():
    """Force the COW path: an outside pin on the page a slot is about to
    write into makes refcount > 1, so the decode step must split it with
    a device-side copy — and the tokens must not change."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block
    prompt = np.random.default_rng(5).integers(
        0, cfg.vocab_size, page + 4, dtype=np.int32
    )
    ref_eng = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=2 * page,
                                prefix_cache=True),
    )
    ref_eng.submit(prompt, 5)
    ref = ref_eng.drain(max_steps=20)[0].tokens

    # 2 slots' worth of pool with one request in flight: the COW
    # split needs a free page to copy into
    eng = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=2 * page,
                                prefix_cache=True),
        params=ref_eng.params,
    )
    eng.submit(prompt, 5)
    eng.step()  # prefill + first decode token
    slot = eng.scheduler.active()[0].slot
    shared = int(eng.kv.page_table[slot, 1])  # the partial write page
    eng.kv.incref(shared)  # phantom second owner
    eng.step()  # next decode write targets the shared page -> COW
    assert eng.stats.cow_copies == 1
    assert int(eng.kv.page_table[slot, 1]) != shared
    assert eng.kv.refcount(shared) == 1  # only the phantom holds it now
    fins = eng.drain(max_steps=20)
    np.testing.assert_array_equal(fins[0].tokens, ref)
    eng.kv.unpin(shared)


def test_cow_reserve_survives_oversubscribed_pool():
    """Regression: a COW split on a bone-dry oversubscribed pool used to
    raise ``RuntimeError("KV cache out of pages")`` mid-decode, killing
    every in-flight request — lifetime-page admission budgeting never
    reserved the split's fresh page for prefix-shared sequences. Now a
    prefix-hit admission budgets one COW reserve page: admissions that
    would consume it are deferred, and the split always finds a page."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, page + 4, dtype=np.int32)
    small = rng.integers(0, cfg.vocab_size, 4, dtype=np.int32)

    ref_eng = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=2 * page),
    )
    ref_eng.submit(prompt, 5)
    ref = ref_eng.drain(max_steps=30)[0].tokens

    # minimal oversubscribed pool: 3 allocatable pages + trash for two
    # 2-page-lifetime slots
    eng = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(
            max_slots=2, max_len=2 * page, prefix_cache=True,
            n_pages=4, preemption=False,
        ),
        params=ref_eng.params,
    )
    # turn 1: index the prompt's full page into the radix tree, park it
    eng.submit(prompt, 5)
    eng.drain(max_steps=30)
    assert eng.kv.cached_pages == 1

    # turn 2: same prompt -> adopts the shared page; its lifetime (2
    # pages) is budgeted +1 for the COW reserve
    eng.submit(prompt, 5)
    eng.step()
    slot = eng.scheduler.active()[0].slot
    assert eng._cow_reserve[slot] == 1
    assert eng._page_need[slot] == 3  # 2 lifetime + 1 reserve

    # a small request whose single page would consume the reserve must
    # NOT admit while the pool's last free page backs the reservation
    eng.submit(small, 3)
    eng.step()
    assert len(eng.scheduler.active()) == 1
    assert len(eng.scheduler.waiting) == 1

    # fork the running slot's write page (refcount 2) and decode past
    # the split: pre-fix this raised "KV cache out of pages"
    shared = int(eng.kv.page_table[slot, 1])
    eng.kv.incref(shared)
    eng.step()
    assert eng.stats.cow_copies == 1
    assert eng._cow_reserve[slot] == 0
    assert eng._page_need[slot] == 2  # reserve consumed by the split
    fins = eng.drain(max_steps=60)
    eng.kv.unpin(shared)
    by_uid = {f.uid: f for f in fins}
    np.testing.assert_array_equal(by_uid[2].tokens, ref)
    assert by_uid[3].finish_reason in ("length", "eos")


def test_pool_filling_request_declines_hit_instead_of_deadlocking():
    """A request whose lifetime fills every allocatable page cannot also
    carry the +1 COW reserve — it must decline the prefix hit (fresh
    prefill shares nothing, so no reserve is needed) rather than wait on
    a budget that can never be met."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block
    prompt = np.random.default_rng(9).integers(
        0, cfg.vocab_size, page + 4, dtype=np.int32
    )
    eng = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(
            max_slots=2, max_len=2 * page, prefix_cache=True,
            n_pages=3, preemption=False,  # one 2-page slot + trash
        ),
    )
    eng.submit(prompt, 5)
    eng.drain(max_steps=30)
    assert eng.kv.cached_pages == 1  # the prompt page is indexed
    eng.submit(prompt, 5)
    fins = eng.drain(max_steps=30)
    assert len(fins) == 1
    assert fins[0].prefix_hit_tokens == 0  # hit declined, not adopted


# ----------------------------------------------------------------------
# Anti-starvation aging
# ----------------------------------------------------------------------


def test_scheduler_skip_counters():
    sch = Scheduler(1)
    reqs = [Request(i, np.array([1, 2]), 2) for i in range(3)]
    for r in reqs:
        sch.submit(r)
    assert sch.skip_count(reqs[0]) == 0
    sch.note_skips([reqs[0], reqs[2]])
    sch.note_skips([reqs[0]])
    assert sch.skip_count(reqs[0]) == 2
    assert sch.skip_count(reqs[1]) == 0
    assert sch.skip_count(reqs[2]) == 1
    sch.admit(0)  # admitting clears the counter
    assert sch.skip_count(reqs[0]) == 0


def test_engine_aging_stops_admitting_around_starved_request():
    """After ``max_skips`` passes of being admitted around, a skipped
    request becomes a barrier: later small requests queue behind it
    instead of jumping it forever, and it admits as soon as its pages
    free up — strictly before anything submitted after it."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block

    def serve(max_skips):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=3, max_len=3 * page, n_pages=6,
                max_skips=max_skips,
            ),
        )
        rng = np.random.default_rng(13)
        hog = eng.submit(
            rng.integers(0, cfg.vocab_size, 2 * page + 4), 3 * page
        )  # 3 pages held for many steps
        eng.step()
        big = eng.submit(
            rng.integers(0, cfg.vocab_size, 2 * page + 4), 3
        )  # needs 3 pages; only 2 free while the hog lives
        smalls = [
            eng.submit(rng.integers(0, cfg.vocab_size, 6), 2)
            for _ in range(4)
        ]
        fins = {f.uid: f for f in eng.drain(max_steps=300)}
        return hog, big, smalls, fins

    # aging on: one skip allowed, then the big request blocks the queue
    hog, big, smalls, fins = serve(max_skips=1)
    early = [u for u in smalls if fins[u].admit_step < fins[big].admit_step]
    held = [u for u in smalls if fins[u].admit_step >= fins[big].admit_step]
    assert len(early) <= 2  # at most the one pass that aged the big one
    assert held, "the barrier must hold some smalls back"
    # aging off: every small jumps the starving big request
    hog, big, smalls, fins = serve(max_skips=0)
    assert all(
        fins[u].admit_step < fins[big].admit_step for u in smalls
    )
