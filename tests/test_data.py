"""Data pipeline: determinism, resumability, shapes."""

import numpy as np

from repro.training.data import EmbedsWrapper, SyntheticLM, TextFileLM


def test_step_addressable_determinism():
    d1 = SyntheticLM(256, 32, 4, seed=7)
    d2 = SyntheticLM(256, 32, 4, seed=7)
    b1, b2 = d1.batch(123), d2.batch(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    assert not np.array_equal(b1["tokens"], d1.batch(124)["tokens"])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(256, 16, 2, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert b["tokens"].dtype == np.int32


def test_text_file(tmp_path):
    p = tmp_path / "x.txt"
    p.write_bytes(b"hello world, this is a test corpus for byte-level lm. " * 10)
    d = TextFileLM(str(p), 16, 2, seed=0)
    b = d.batch(5)
    assert b["tokens"].shape == (2, 16)
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 256).all()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_embeds_wrapper():
    d = EmbedsWrapper(SyntheticLM(64, 8, 2, seed=0), d_model=32, n_pos_streams=3)
    b = d.batch(0)
    assert b["embeds"].shape == (2, 8, 32)
    assert b["positions"].shape == (2, 8, 3)
    np.testing.assert_array_equal(b["embeds"], d.batch(0)["embeds"])
