"""Observability layer: metrics registry, span tracer, exporters, and
their engine wiring.

The load-bearing contracts, in rough order of importance:

  * Tracing is observation only — a tracer-disabled engine emits
    bit-identical tokens AND does literally zero obs work on the decode
    hot path (proved by counting calls into the tracer's clock).
  * ``stats_summary()`` keeps its exact schema: BENCH trajectories and
    the goodput report parse it by key.
  * The Prometheus snapshot and ``stats_summary()`` are two views of
    the same registry and must agree.
  * Per-request spans survive preemption+resume with sane ordering,
    and the Perfetto export of a real serve validates (matched B/E,
    monotonic timestamps, nonempty slot tracks).
  * ``Engine.reset_stats()`` mid-traffic resets registry and ring
    atomically: open spans close as truncated, nothing dangles.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.guards import DispatchGuard
from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.obs import (
    MetricsRegistry,
    NULL_TRACER,
    Tracer,
    validate_trace_file,
)
from repro.obs.perfetto import TraceValidationError, validate_trace
from repro.obs.prom import parse, render, write_snapshot
from repro.serving import Engine, EngineConfig, ScheduleParams
from repro.serving.router import ReplicaRouter


def _smoke_cfg(**kw):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=2, vocab_size=128, **kw
    )


def _mesh():
    return make_local_mesh()


# ----------------------------------------------------------------------
# metrics primitives (no engine)
# ----------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(4)
    assert c.value == 5 and isinstance(c.value, int)
    with pytest.raises(ValueError):
        c.inc(-1)

    lc = reg.counter("lc_total", "labeled", labelname="bucket")
    lc.inc(2, label=(4, 32))
    lc.inc(1, label=(4, 32))
    lc.inc(7, label=(8, 64))
    assert lc.get((4, 32)) == 3 and lc.value == 10

    g = reg.gauge("g", "a gauge")
    g.set(3)
    g.inc(-1)
    assert g.value == 2

    h = reg.histogram("h_seconds", "a histogram")
    for v in (0.001, 0.002, 0.003, 0.004):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(0.010)
    assert h.percentile(50) == pytest.approx(
        float(np.percentile([0.001, 0.002, 0.003, 0.004], 50))
    )
    # cumulative buckets are monotone and end at count
    cum = h.cumulative_buckets()
    assert [n for _, n in cum] == sorted(n for _, n in cum)
    assert cum[-1][1] == 4

    # get-or-create returns the same object; kind mismatch is an error
    assert reg.counter("c_total", "a counter") is c
    with pytest.raises(TypeError):
        reg.gauge("c_total", "wrong kind")


def test_registry_merge_sums_and_concatenates():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n_total", "n").inc(2)
    b.counter("n_total", "n").inc(3)
    a.counter("lab_total", "l", labelname="k").inc(1, label="x")
    b.counter("lab_total", "l", labelname="k").inc(5, label="x")
    b.counter("only_b_total", "o").inc(9)
    a.histogram("lat_seconds", "l").observe(1.0)
    b.histogram("lat_seconds", "l").observe(3.0)
    m = MetricsRegistry.merged([a, b])
    assert m["n_total"].value == 5
    assert m["lab_total"].get("x") == 6
    assert m["only_b_total"].value == 9
    # merged percentiles are over the union of raw samples, not
    # averages of per-registry percentiles
    assert m["lat_seconds"].count == 2
    assert m["lat_seconds"].percentile(50) == pytest.approx(2.0)
    # sources unchanged
    assert a["n_total"].value == 2 and b["n_total"].value == 3


def test_prom_render_parse_roundtrip():
    reg = MetricsRegistry()
    reg.counter("repro_x_total", "things").inc(7)
    lc = reg.counter("repro_y_total", "labeled", labelname="bucket")
    lc.inc(3, label=(4, 32))
    reg.gauge("repro_g", "gauge").set(2)
    h = reg.histogram("repro_h_seconds", "hist")
    h.observe(0.5)
    h.observe(2.0)
    text = render(reg)
    assert "# HELP repro_x_total things" in text
    assert "# TYPE repro_h_seconds histogram" in text
    got = parse(text)
    assert got["repro_x_total"] == 7.0
    assert got['repro_y_total{bucket="4x32"}'] == 3.0
    assert got["repro_g"] == 2.0
    assert got["repro_h_seconds_count"] == 2.0
    assert got["repro_h_seconds_sum"] == pytest.approx(2.5)
    assert got['repro_h_seconds_bucket{le="+Inf"}'] == 2.0
    with pytest.raises(ValueError):
        parse("repro_bad_total not-a-number\n")


# ----------------------------------------------------------------------
# tracer primitives (no engine)
# ----------------------------------------------------------------------


def test_tracer_interning_and_ring_wrap():
    tr = Tracer(capacity=8)
    t = tr.track("t")
    assert tr.track("t") == t  # stable ids
    names = [tr.name(f"n{i}") for i in range(20)]
    for n in names:
        tr.instant(t, n)
    assert tr.n_recorded == 20 and tr.n_events == 8
    evs = tr.events()
    # oldest-first window over the last `capacity` events
    assert [e["name"] for e in evs] == [f"n{i}" for i in range(12, 20)]
    assert all(
        a["ts_ns"] <= b["ts_ns"] for a, b in zip(evs, evs[1:])
    )


def test_tracer_reset_truncates_open_spans():
    tr = Tracer(capacity=64)
    t = tr.track("t")
    n = tr.name("span")
    tr.begin(t, n)
    tr.begin(t, n)  # nested
    assert tr.open_spans() == {"t": ["span", "span"]}
    tr.reset()
    assert tr.truncated_spans == 2
    assert tr.open_spans() == {} and tr.n_events == 0
    # ends for pre-reset spans are no-ops, not corruption
    tr.end(t, n)
    assert tr.n_events == 0
    # fresh spans after reset work normally
    tr.begin(t, n)
    tr.end(t, n)
    assert [e["kind"] for e in tr.events()] == [0, 1]


def test_null_tracer_surface():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.begin(0, 0) == 0
    NULL_TRACER.end(0, 0)
    NULL_TRACER.reset()
    assert NULL_TRACER.events() == []
    with pytest.raises(RuntimeError):
        NULL_TRACER.export_perfetto("/dev/null")


def test_perfetto_validator_rejects_garbage(tmp_path):
    with pytest.raises(TraceValidationError):
        validate_trace({"traceEvents": "nope"})
    # unmatched E for a never-opened span
    bad = {
        "traceEvents": [
            {"ph": "E", "pid": 0, "tid": 0, "ts": 1, "name": "x"},
        ]
    }
    with pytest.raises(TraceValidationError):
        validate_trace(bad)


# ----------------------------------------------------------------------
# engine wiring
# ----------------------------------------------------------------------


def test_traced_engine_spans_survive_preempt_resume(tmp_path):
    """Per-request lifecycle under preemption: the victim's decode span
    closes at preemption (a1=1), swap_out/swap_in instants bracket the
    host round-trip, a new decode span opens at resume, and the whole
    timeline exports to a valid Perfetto file."""
    cfg = _smoke_cfg()
    eng = Engine(
        cfg,
        _mesh(),
        engine_cfg=EngineConfig(max_slots=2, max_len=128, trace=True),
    )
    rng = np.random.default_rng(1)
    bg = [
        eng.submit(rng.integers(1, 127, 8).astype(np.int32), 40)
        for _ in range(2)
    ]
    for _ in range(6):
        eng.step()
    eng.submit(
        rng.integers(1, 127, 8).astype(np.int32),
        4,
        schedule=ScheduleParams(priority=3, deadline_s=120.0),
    )
    fins = eng.drain(max_steps=500)
    assert eng.stats.preemptions >= 1
    victims = [f.uid for f in fins if f.preemptions > 0]
    assert victims and set(victims) <= set(bg)
    uid = victims[0]

    evs = [e for e in eng.tracer.events() if e["a0"] == uid]
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    # preemption closed the decode span with the marker arg...
    closes = [
        e for e in by_name["decode"] if e["kind"] == 1 and e["a1"] == 1
    ]
    assert len(closes) == 1
    # ...and the lifecycle instants appear in causal order
    order = [
        by_name["preempt"][0]["ts_ns"],
        by_name["swap_out"][0]["ts_ns"],
        by_name["swap_in"][0]["ts_ns"],
        by_name["finished"][0]["ts_ns"],
    ]
    assert order == sorted(order)
    assert closes[0]["ts_ns"] <= by_name["swap_out"][0]["ts_ns"]
    # resume opened a fresh decode span after the swap_in
    reopens = [
        e
        for e in by_name["decode"]
        if e["kind"] == 0 and e["ts_ns"] >= by_name["swap_in"][0]["ts_ns"]
    ]
    assert reopens
    # queue-churn instants from the scheduler hook
    kinds = {e["name"] for e in eng.tracer.events()}
    assert {"submit", "admit", "resume", "queued", "prefill"} <= kinds

    out = tmp_path / "trace.json"
    n = eng.export_perfetto(str(out))
    rep = validate_trace_file(str(out))
    assert rep["events"] == n and rep["slot_tracks"] >= 1
    assert rep["spans"] > 0
    # per-step engine spans correlate compiles: steady-state decode
    # steps carry a zero compile delta
    steps = [
        e
        for e in eng.tracer.events()
        if e["name"] == "decode_step" and e["kind"] == 1
    ]
    assert steps and all(e["a1"] >= 0 for e in steps)
    assert any(e["a1"] == 0 for e in steps)


def test_tracer_disabled_bit_identical_and_zero_obs_work(monkeypatch):
    """trace=False must be free: same tokens, and not a single call
    into the tracer clock from the serve loop."""
    import repro.obs.trace as trace_mod

    cfg = _smoke_cfg()
    mesh = _mesh()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 127, 12).astype(np.int32) for _ in range(3)]

    calls = {"n": 0}
    real = trace_mod.perf_counter_ns

    def counting():
        calls["n"] += 1
        return real()

    monkeypatch.setattr(trace_mod, "perf_counter_ns", counting)

    streams = {}
    for on in (False, True):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(max_slots=2, max_len=64, trace=on),
        )
        calls["n"] = 0
        for p in prompts:
            eng.submit(p, 8)
        fins = eng.drain(max_steps=300)
        streams[on] = [
            f.tokens.tolist() for f in sorted(fins, key=lambda f: f.uid)
        ]
        if on:
            assert calls["n"] > 0 and eng.tracer.n_recorded > 0
        else:
            assert calls["n"] == 0, (
                "disabled engine touched the tracer clock "
                f"{calls['n']} time(s)"
            )
    assert streams[True] == streams[False]


def test_stats_summary_golden_keys():
    """The exact stats_summary schema — BENCH trajectories, bench_diff
    and the goodput report all index into this dict by key."""
    cfg = _smoke_cfg()
    eng = Engine(
        cfg,
        _mesh(),
        engine_cfg=EngineConfig(
            max_slots=2, max_len=64, prefix_cache=True, trace=True
        ),
    )
    rng = np.random.default_rng(6)
    for _ in range(3):
        eng.submit(rng.integers(1, 127, 12).astype(np.int32), 4)
    eng.drain(max_steps=300)
    s = eng.stats_summary()
    assert list(s) == [
        "requests_finished",
        "generated_tokens",
        "by_sampler",
        "pages_reclaimed_early",
        "prefix_cache",
        "preemption",
        "rejected",
        "slo",
        "ttft_ms",
        "queue_wait_ms",
        "dispatch_guard",
        "prefill_calls",
        "prefill_requests",
        "mean_prefill_batch",
        "prefill_by_bucket",
        "prefill_tokens",
        "prefill_s",
        "decode_s",
        "total_s",
        "decode_steps",
        "tok_s",
        "decode_tok_s",
        "prefill_tok_s",
        "p50_token_latency_ms",
        "p95_token_latency_ms",
        "p99_token_latency_ms",
        "mean_occupancy",
        "min_occupancy",
        "max_occupancy",
        "roofline",
    ]
    assert set(s["roofline"]) >= {
        "available",
        "arithmetic_intensity",
        "bottleneck",
    }
    assert set(s["prefix_cache"]) == {
        "enabled",
        "lookups",
        "hit_tokens",
        "prompt_tokens",
        "hit_pages",
        "hit_rate",
        "cow_copies",
        "decode_indexed_pages",
        "inserted_pages",
        "evicted_pages",
        "cached_pages",
    }
    assert set(s["preemption"]) == {
        "preemptions",
        "resumes",
        "swap_outs",
        "swap_ins",
        "out_pages",
        "in_pages",
        "out_bytes",
        "in_bytes",
        "pinned_pages",
    }
    assert set(s["dispatch_guard"]) == {"step_compiles", "host_syncs"}
    assert set(s["slo"]) == {"with_deadline", "met", "attainment"}
    assert set(s["ttft_ms"]) == {"p50_ms", "p95_ms", "p99_ms"}
    assert s["by_sampler"] == {"greedy": {"requests": 3, "tokens": 12}}
    assert s["requests_finished"] == 3
    # one sanctioned host sync per decode step, plus one per prefill
    assert s["dispatch_guard"]["host_syncs"] >= s["decode_steps"]
    # everything is JSON-serializable (the BENCH payload requires it)
    json.dumps(s)


def test_prom_snapshot_agrees_with_stats_summary(tmp_path):
    cfg = _smoke_cfg()
    eng = Engine(
        cfg, _mesh(), engine_cfg=EngineConfig(max_slots=2, max_len=64)
    )
    rng = np.random.default_rng(7)
    for _ in range(3):
        eng.submit(rng.integers(1, 127, 10).astype(np.int32), 5)
    eng.drain(max_steps=300)
    s = eng.stats_summary()
    out = tmp_path / "metrics.prom"
    write_snapshot(str(out), eng.metrics)
    got = parse(out.read_text())
    assert got["repro_serve_requests_finished_total"] == s[
        "requests_finished"
    ]
    assert got["repro_serve_generated_tokens_total"] == s[
        "generated_tokens"
    ]
    assert got["repro_serve_decode_steps_total"] == s["decode_steps"]
    assert got["repro_serve_prefill_tokens_total"] == s["prefill_tokens"]
    assert got['repro_serve_finished_by_sampler_total{sampler="greedy"}'] \
        == s["by_sampler"]["greedy"]["requests"]
    assert got["repro_serve_step_latency_seconds_count"] == s[
        "decode_steps"
    ]
    assert got["repro_serve_host_syncs_total"] == s["dispatch_guard"][
        "host_syncs"
    ]


def test_reset_stats_mid_traffic_is_atomic(tmp_path):
    """reset_stats() while requests are in flight: the registry zeroes,
    open spans close as truncated (no orphan B), and both the summary
    and a subsequent export stay consistent."""
    cfg = _smoke_cfg()
    eng = Engine(
        cfg,
        _mesh(),
        engine_cfg=EngineConfig(max_slots=2, max_len=64, trace=True),
    )
    rng = np.random.default_rng(8)
    for _ in range(2):
        eng.submit(rng.integers(1, 127, 10).astype(np.int32), 12)
    for _ in range(3):
        eng.step()
    assert eng.tracer.open_spans()  # decode spans are live mid-traffic
    before = eng.stats.decode_steps
    assert before > 0

    eng.reset_stats()
    assert eng.tracer.truncated_spans > 0
    assert eng.tracer.open_spans() == {}
    assert eng.stats.decode_steps == 0 and eng.stats.finished == 0
    assert eng.metrics["repro_serve_decode_steps_total"].value == 0

    fins = eng.drain(max_steps=300)
    assert len(fins) == 2  # traffic survives the reset
    s = eng.stats_summary()
    assert s["requests_finished"] == 2
    assert s["decode_steps"] > 0
    # the post-reset ring still exports cleanly: pre-reset decode spans
    # were force-closed, so their late end() calls recorded nothing
    out = tmp_path / "after_reset.json"
    eng.export_perfetto(str(out))
    validate_trace_file(str(out))
    # the stats view rebind is total: ServeStats/SwapStats/PrefixStats
    # all write into the fresh registry
    assert eng.stats.registry is eng.metrics
    assert eng.swap.stats.out_pages == 0


def test_engine_config_trace_validation():
    with pytest.raises(ValueError):
        EngineConfig(max_slots=1, max_len=32, trace=-4)
    assert EngineConfig(max_slots=1, max_len=32, trace=1024).trace == 1024


def test_router_merged_stats_and_export(tmp_path):
    cfg = _smoke_cfg()
    router = ReplicaRouter(
        cfg,
        replicas=1,
        engine_cfg=EngineConfig(max_slots=2, max_len=64, trace=True),
    )
    rng = np.random.default_rng(9)
    for _ in range(3):
        router.submit(rng.integers(1, 127, 10).astype(np.int32), 4)
    fins = router.drain(max_steps=300)
    assert len(fins) == 3
    s = router.stats_summary()
    assert s["requests_finished"] == 3
    assert len(s["per_replica"]) == 1
    assert s["per_replica"][0]["requests_finished"] == 3
    # merged registry agrees with the single replica's own
    assert (
        router.merged_metrics()["repro_serve_generated_tokens_total"].value
        == router.engines[0].stats.generated
    )
    out = tmp_path / "router_trace.json"
    n = router.export_perfetto(str(out))
    rep = validate_trace_file(str(out))
    assert rep["events"] == n and rep["slot_tracks"] >= 1
    router.reset_stats()
    assert router.stats_summary()["requests_finished"] == 0


def test_dispatch_guard_feeds_metrics_registry():
    reg = MetricsRegistry()
    with DispatchGuard(
        max_compiles=None, raise_on_sync=False, metrics=reg
    ):
        y = jax.jit(lambda x: x + 1)(jnp.arange(3.0))
        jax.device_get(y)
    assert reg["repro_guard_explicit_syncs_total"].value == 1
    assert reg["repro_guard_compiles_total"].value >= 1
    assert reg["repro_guard_implicit_syncs_total"].value == 0
    # counters accumulate across guarded regions on the same registry
    with DispatchGuard(
        max_compiles=None, raise_on_sync=False, metrics=reg
    ):
        jax.device_get(jnp.zeros(2))
    assert reg["repro_guard_explicit_syncs_total"].value == 2
