"""Mamba2 SSD: chunked scan vs naive recurrence oracle; decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_lib


def naive_ssd(x, dt, A, B, C):
    """Sequential oracle: S_t = S_{t-1} exp(dt_t A) + dt_t B_t x_t;
    y_t = C_t . S_t. Shapes as in _ssd_chunked."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    S = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])  # (b,h)
        dBx = np.einsum(
            "bh,bn,bhp->bhpn", np.asarray(dt[:, t]), np.asarray(B[:, t]),
            np.asarray(x[:, t]),
        )
        S = S * dA[..., None, None] + dBx
        ys[:, t] = np.einsum("bhpn,bn->bhp", S, np.asarray(C[:, t]))
    return ys, S


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_ssd_matches_naive(chunk):
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 32, 3, 4, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random(h) - 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y, final = ssm_lib._ssd_chunked(x / dt[..., None], dt, A, B, C, chunk)
    # _ssd_chunked multiplies x by dt internally; feed x/dt so the oracle's
    # dt_t B_t x_t matches.
    y_ref, S_ref = naive_ssd(
        np.asarray(x / dt[..., None]), dt, A, B, C
    )
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), S_ref, rtol=2e-4, atol=2e-4)


def _cfg():
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=64, num_heads=0,
        num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=64,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, dtype="float32",
    )


def test_train_decode_consistency():
    """Stepping the recurrent decode path over a sequence must reproduce
    the chunked train forward."""
    cfg = _cfg()
    spec = ssm_lib.SsmSpec(cfg)
    key = jax.random.PRNGKey(0)
    params = ssm_lib.init_ssm(key, spec)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.3, jnp.float32)
    y_train, cache_final = ssm_lib.apply_ssm_train(
        spec, params, x, return_state=True
    )
    cache = ssm_lib.init_ssm_cache(spec, 2, jnp.float32)
    ys = []
    for t in range(16):
        y_t, cache = ssm_lib.apply_ssm_decode(spec, params, x[:, t : t + 1], cache)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_train), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(cache["state"]), np.asarray(cache_final["state"]),
        rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        np.asarray(cache["conv"]), np.asarray(cache_final["conv"]),
        rtol=2e-3, atol=2e-3,
    )


def test_prefill_then_decode_continues():
    """Prefill state + one decode step == train forward over s+1 tokens."""
    cfg = _cfg()
    spec = ssm_lib.SsmSpec(cfg)
    params = ssm_lib.init_ssm(jax.random.PRNGKey(1), spec)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 17, cfg.d_model)) * 0.3, jnp.float32)
    y_full, _ = ssm_lib.apply_ssm_train(spec, params, x)
    _, cache = ssm_lib.apply_ssm_train(spec, params, x[:, :16], return_state=True)
    y_last, _ = ssm_lib.apply_ssm_decode(spec, params, x[:, 16:17], cache)
    np.testing.assert_allclose(
        np.asarray(y_last[:, 0]), np.asarray(y_full[:, 16]),
        rtol=2e-3, atol=2e-3,
    )
