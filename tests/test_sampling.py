"""Sampling subsystem: fused in-jit sampler vs host oracle, seeded
determinism across schedules, greedy parity, truncation properties,
host-sync parity with the greedy baseline, and early-EOS page
reclamation."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Server
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.sampling import (
    base_key_data,
    reference_sample,
    sample_logits,
)


def _smoke_cfg(**kw):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=2, vocab_size=128, **kw
    )


def _draw_many(logits_row, sp: SamplingParams, n: int) -> np.ndarray:
    """n independent draws of the fused sampler on one logits row (one
    row per sample index — exactly how a request's stream advances)."""
    v = logits_row.shape[-1]
    b = np.broadcast_to(logits_row, (n, v))
    toks = sample_logits(
        jnp.asarray(b, jnp.float32),
        jnp.full((n,), sp.temperature, jnp.float32),
        jnp.full((n,), sp.top_k, jnp.int32),
        jnp.full((n,), sp.top_p, jnp.float32),
        jnp.full((n,), sp.repetition_penalty, jnp.float32),
        jnp.broadcast_to(jnp.asarray(base_key_data(sp.seed)), (n, 2)),
        jnp.arange(n, dtype=jnp.int32),
        jnp.zeros((n, v), jnp.bool_),
    )
    return np.asarray(toks)


# ----------------------------------------------------------------------
# SamplingParams
# ----------------------------------------------------------------------


def test_sampling_params_validation_and_kind():
    assert SamplingParams().kind == "greedy"
    assert SamplingParams().is_greedy
    sp = SamplingParams(temperature=0.7, top_k=5, top_p=0.9)
    assert sp.kind == "temperature+top_k+top_p"
    assert SamplingParams(temperature=1.0).kind == "temperature"
    # greedy regardless of other knobs when temperature == 0
    assert SamplingParams(top_k=5, top_p=0.5).kind == "greedy"
    assert SamplingParams(top_k=5).is_plain
    # a live penalty changes greedy output and needs the sampler state
    pen = SamplingParams(repetition_penalty=1.2)
    assert pen.is_greedy and not pen.is_plain
    assert pen.kind == "greedy+rep_pen"
    for bad in (
        dict(temperature=-0.1),
        dict(top_k=-1),
        dict(top_p=0.0),
        dict(top_p=1.5),
        dict(repetition_penalty=0.0),
        dict(seed=-1),
    ):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


def test_base_key_is_schedule_independent():
    # key depends only on the seed — the whole determinism story
    np.testing.assert_array_equal(base_key_data(7), base_key_data(7))
    assert not np.array_equal(base_key_data(7), base_key_data(8))
    k = base_key_data((1 << 40) + 3)
    assert k.dtype == np.uint32 and k.shape == (2,)


# ----------------------------------------------------------------------
# Fused sampler vs host oracle (differential)
# ----------------------------------------------------------------------


def test_fused_sampler_matches_host_reference():
    """Every (params, draw) cell: the fused in-jit path and the numpy
    oracle pick the identical token (same noise bits, independent
    filtering code)."""
    rng = np.random.default_rng(0)
    v, draws = 48, 16
    logits = rng.normal(0.0, 3.0, size=(v,)).astype(np.float32)
    seen = rng.random(v) < 0.2
    grid = [
        SamplingParams(),  # greedy
        SamplingParams(temperature=0.5, seed=11),
        SamplingParams(temperature=1.3, top_k=7, seed=12),
        SamplingParams(temperature=0.9, top_p=0.8, seed=13),
        SamplingParams(temperature=0.8, top_k=10, top_p=0.7, seed=14),
        SamplingParams(
            temperature=1.0, repetition_penalty=1.4, seed=15
        ),
    ]
    b = len(grid) * draws
    rows = dict(
        logits=np.broadcast_to(logits, (b, v)).copy(),
        temp=np.empty((b,), np.float32),
        top_k=np.empty((b,), np.int32),
        top_p=np.empty((b,), np.float32),
        rep=np.empty((b,), np.float32),
        key=np.empty((b, 2), np.uint32),
        idx=np.empty((b,), np.int32),
    )
    want = []
    for gi, sp in enumerate(grid):
        for d in range(draws):
            r = gi * draws + d
            rows["temp"][r] = sp.temperature
            rows["top_k"][r] = sp.top_k
            rows["top_p"][r] = sp.top_p
            rows["rep"][r] = sp.repetition_penalty
            rows["key"][r] = base_key_data(sp.seed)
            rows["idx"][r] = d
            want.append(
                reference_sample(logits, sp, sample_idx=d, seen=seen)
            )
    got = np.asarray(
        jax.jit(sample_logits)(
            jnp.asarray(rows["logits"]),
            jnp.asarray(rows["temp"]),
            jnp.asarray(rows["top_k"]),
            jnp.asarray(rows["top_p"]),
            jnp.asarray(rows["rep"]),
            jnp.asarray(rows["key"]),
            jnp.asarray(rows["idx"]),
            jnp.broadcast_to(jnp.asarray(seen), (b, v)),
        )
    )
    np.testing.assert_array_equal(got, np.asarray(want))


# ----------------------------------------------------------------------
# Truncation properties on crafted logits
# ----------------------------------------------------------------------


def test_top_k_truncates_and_covers():
    """top_k=k on well-separated logits: every draw lands in the top-k
    set, and (high temperature, many draws) every top-k token appears."""
    v, k = 16, 4
    logits = np.linspace(4.0, -4.0, v).astype(np.float32)  # descending
    toks = _draw_many(logits, SamplingParams(
        temperature=5.0, top_k=k, seed=3), 256)
    assert set(np.unique(toks)) <= set(range(k))
    assert set(np.unique(toks)) == set(range(k))  # coverage at high temp


def test_top_p_keeps_smallest_mass_prefix():
    """Crafted distribution p = [.5, .3, .1, .05, .05]: top_p=0.85 keeps
    exactly {0, 1, 2} (the smallest prefix whose mass reaches 0.85),
    and tighter p=0.45 keeps only the argmax."""
    probs = np.array([0.5, 0.3, 0.1, 0.05, 0.05], np.float32)
    logits = np.log(probs)
    toks = _draw_many(logits, SamplingParams(
        temperature=1.0, top_p=0.85, seed=5), 512)
    assert set(np.unique(toks)) == {0, 1, 2}
    toks = _draw_many(logits, SamplingParams(
        temperature=1.0, top_p=0.45, seed=5), 64)
    assert set(np.unique(toks)) == {0}


def test_top_p_disabled_reaches_tail():
    probs = np.array([0.5, 0.3, 0.1, 0.05, 0.05], np.float32)
    toks = _draw_many(np.log(probs), SamplingParams(
        temperature=2.0, seed=6), 2048)
    assert set(np.unique(toks)) == set(range(5))


def test_candidate_cap_truncates_to_top_c():
    """The static candidate cap confines draws to the top-C logits (the
    O(V log C) production path for big vocabs) and matches the host
    oracle given the same cap."""
    v, c = 32, 4
    logits = np.linspace(3.0, -3.0, v).astype(np.float32)
    sp = SamplingParams(temperature=8.0, seed=9)  # near-uniform
    n = 256
    b = np.broadcast_to(logits, (n, v))
    toks = np.asarray(sample_logits(
        jnp.asarray(b, jnp.float32),
        jnp.full((n,), sp.temperature, jnp.float32),
        jnp.zeros((n,), jnp.int32),
        jnp.ones((n,), jnp.float32),
        jnp.ones((n,), jnp.float32),
        jnp.broadcast_to(jnp.asarray(base_key_data(sp.seed)), (n, 2)),
        jnp.arange(n, dtype=jnp.int32),
        jnp.zeros((n, v), jnp.bool_),
        None,
        c,
    ))
    assert set(np.unique(toks)) == set(range(c))  # confined AND covered
    want = [
        reference_sample(logits, sp, sample_idx=d, candidates=c)
        for d in range(8)
    ]
    np.testing.assert_array_equal(toks[:8], want)


def test_engine_rejects_top_k_beyond_candidate_cap():
    from repro.serving.scheduler import Scheduler

    eng = Engine.__new__(Engine)  # no model needed for the check
    eng.ecfg = EngineConfig(max_slots=1, max_len=64, sampler_candidates=8)
    eng.scheduler = Scheduler(1)
    eng._uid = 0
    # submit also sanity-checks the request against the page pool; give
    # the model-less skeleton a one-slot pool's worth of geometry
    from repro.serving import PagedKVCache

    kv_cfg = registry.get_smoke("qwen3-1.7b").replace(
        num_layers=1, num_heads=2, num_kv_heads=1, head_dim=8
    )
    eng.kv = PagedKVCache(
        kv_cfg,
        max_slots=1,
        max_len=eng.ecfg.rounded(kv_cfg.attn_block).max_len,
    )
    with pytest.raises(ValueError, match="candidate cap"):
        Engine.submit(
            eng, np.arange(4, dtype=np.int32), 2,
            sampling=SamplingParams(temperature=1.0, top_k=9),
        )
    # at or below the cap is fine
    Engine.submit(
        eng, np.arange(4, dtype=np.int32), 2,
        sampling=SamplingParams(temperature=1.0, top_k=8),
    )


def test_repetition_penalty_discourages_seen_tokens():
    """Greedy with a penalty: the (seen) argmax loses to the runner-up
    once the penalty outweighs its margin; rep=1.0 is exact identity."""
    v = 8
    logits = np.zeros((1, v), np.float32)
    logits[0, 0], logits[0, 1] = 2.0, 1.9  # near-tied top two
    seen = np.zeros((1, v), bool)
    seen[0, 0] = True

    def greedy_with(rep):
        return int(np.asarray(sample_logits(
            jnp.asarray(logits),
            jnp.zeros((1,), jnp.float32),  # temperature 0
            jnp.zeros((1,), jnp.int32),
            jnp.ones((1,), jnp.float32),
            jnp.full((1,), rep, jnp.float32),
            jnp.asarray(base_key_data(0))[None],
            jnp.zeros((1,), jnp.int32),
            jnp.asarray(seen),
        ))[0])

    assert greedy_with(1.0) == 0  # identity penalty: raw argmax
    assert greedy_with(1.5) == 1  # seen token penalized below runner-up


# ----------------------------------------------------------------------
# Engine: greedy parity, determinism, sync parity, reclamation
# ----------------------------------------------------------------------


def test_temperature_zero_exact_greedy_parity():
    """temperature=0 — even with top_k/top_p/penalty knobs set — must
    reproduce the Server oracle's argmax tokens bit-exactly (penalty is
    only identity-safe at its default 1.0, so keep it there)."""
    cfg = _smoke_cfg(sparse_attention=True)
    mesh = make_local_mesh()
    server = Server(cfg, mesh)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(3, 8), dtype=np.int32
    )
    ref = server.generate(prompts, 5)
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=3, max_len=128),
        params=server.params,
    )
    for b in range(3):
        eng.submit(
            prompts[b], 5,
            sampling=SamplingParams(top_k=3, top_p=0.9, seed=b),
        )
    fins = sorted(eng.drain(max_steps=50), key=lambda f: f.uid)
    np.testing.assert_array_equal(
        np.stack([f.tokens for f in fins]), ref
    )
    assert eng.stats_summary()["by_sampler"] == {
        "greedy": {"requests": 3, "tokens": 15}
    }


def test_seeded_determinism_across_admission_and_buckets():
    """Same seeds, radically different schedule — different slot count,
    submission order, step interleaving, and prefill bucket composition —
    must yield bit-identical tokens per request."""
    cfg = _smoke_cfg(sparse_attention=True)
    mesh = make_local_mesh()
    rng = np.random.default_rng(23)
    page = cfg.attn_block
    plens = [6, 11, page + 3, 9, 2 * page + 1]
    reqs = [
        (
            rng.integers(0, cfg.vocab_size, p).astype(np.int32),
            SamplingParams(
                temperature=0.9, top_k=25, top_p=0.95, seed=100 + i
            ),
        )
        for i, p in enumerate(plens)
    ]

    # run A: all submitted up front, 4 slots -> big admission groups
    eng_a = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(max_slots=4, max_len=4 * page),
    )
    uids_a = {
        eng_a.submit(p, 6, sampling=sp): i
        for i, (p, sp) in enumerate(reqs)
    }
    toks_a = {
        uids_a[f.uid]: f.tokens for f in eng_a.drain(max_steps=80)
    }

    # run B: reversed order, 2 slots, interleaved steps -> different
    # slots, different buckets, mid-flight arrivals, slot reuse
    eng_b = Engine(
        cfg, mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=4 * page),
        params=eng_a.params,
    )
    fins_b = []
    uids_b = {}
    for i in reversed(range(len(reqs))):
        p, sp = reqs[i]
        uids_b[eng_b.submit(p, 6, sampling=sp)] = i
        fins_b += eng_b.step()
        fins_b += eng_b.step()
    fins_b += eng_b.drain(max_steps=120)
    toks_b = {uids_b[f.uid]: f.tokens for f in fins_b}

    assert sorted(toks_a) == sorted(toks_b) == list(range(len(reqs)))
    for i in toks_a:
        np.testing.assert_array_equal(toks_a[i], toks_b[i])
    # the sampled runs actually sampled (not an all-greedy accident)
    assert list(eng_a.stats_summary()["by_sampler"]) == [
        "temperature+top_k+top_p"
    ]


def test_sampled_decode_same_host_syncs_as_greedy(monkeypatch):
    """Acceptance: sampling runs inside the jit'd step — a sampled trace
    costs exactly as many jit calls and host syncs as the greedy
    baseline on identical traffic."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab_size, size=(3, 8), dtype=np.int32
    )

    def serve(sampling):
        eng = Engine(
            cfg, mesh,
            engine_cfg=EngineConfig(max_slots=3, max_len=64),
        )
        counters = {"sync": 0, "decode": 0, "prefill": 0}
        real_sync = jax.block_until_ready
        monkeypatch.setattr(
            jax, "block_until_ready",
            lambda x: (counters.__setitem__(
                "sync", counters["sync"] + 1), real_sync(x))[1],
        )
        def count(name, fn):
            return lambda *a: (counters.__setitem__(
                name, counters[name] + 1), fn(*a))[1]

        # count plain and sampled variants together: the trace picks one
        eng._decode = count("decode", eng._decode)
        eng._decode_sampled = count("decode", eng._decode_sampled)
        eng._prefill = count("prefill", eng._prefill)
        eng._prefill_sampled = count("prefill", eng._prefill_sampled)
        for b in range(3):
            eng.submit(prompts[b], 6, sampling=sampling)
        fins = eng.drain(max_steps=40)
        monkeypatch.undo()
        assert len(fins) == 3
        return counters

    greedy = serve(None)
    sampled = serve(
        SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=5)
    )
    assert greedy["decode"] > 0 and greedy["prefill"] > 0
    assert sampled == greedy  # same calls, same syncs, knob for knob


def test_eos_early_finish_reclaims_budget_pages():
    """A sequence that hits EOS mid-decode hands its unused lifetime
    reservation back: the reclaimed pages are counted in Stats and a
    queued request is admitted strictly earlier than in the no-EOS run."""
    cfg = _smoke_cfg()
    mesh = make_local_mesh()
    page = cfg.attn_block
    rng = np.random.default_rng(31)
    prompt_a = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    prompt_b = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    gen = page  # lifetime needs a 2nd page; first tokens stay on page 1

    def run(eos_id):
        # 2 usable pages: A's lifetime reservation (2 pages) blocks B
        # until A gives pages back
        eng = Engine(
            cfg, mesh,
            engine_cfg=EngineConfig(
                max_slots=2, max_len=2 * page, n_pages=3
            ),
        )
        uid_a = eng.submit(prompt_a, gen, eos_id=eos_id)
        uid_b = eng.submit(prompt_b, gen)
        fins = eng.drain(max_steps=200)
        by_uid = {f.uid: f for f in fins}
        return by_uid[uid_a], by_uid[uid_b], eng.stats_summary()

    # learn A's greedy stream, then replay with an early token as EOS
    fin_a, fin_b, stats = run(None)
    assert stats["pages_reclaimed_early"] == 0
    eos = int(fin_a.tokens[1])
    k = [int(t) for t in fin_a.tokens].index(eos)
    assert k + 1 < gen  # the replay will finish early

    fin_a2, fin_b2, stats2 = run(eos)
    assert fin_a2.finish_reason == "eos"
    assert len(fin_a2.tokens) == k + 1
    # unused reservation counted: A never touched its 2nd page
    assert stats2["pages_reclaimed_early"] == 1
    # and the budget freed early: B starts strictly sooner than before
    assert fin_b2.admit_step < fin_b.admit_step
