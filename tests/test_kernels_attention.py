"""Pallas block-sparse attention vs oracle: pattern/shape sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attn_pattern as ap
from repro.kernels import ops, ref

SHAPES = [
    # (B, H, S, D, block)
    (2, 2, 256, 64, 64),
    (1, 4, 512, 64, 128),
    (2, 1, 512, 128, 128),
]


def _mk(b, h, s, d, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [False, True])
def test_pallas_matches_oracle(shape, causal):
    b, h, s, d, blk = shape
    cfg = ap.AttentionPatternConfig(
        block=blk, local_blocks=1, max_stride=0, global_blocks=1
    )
    mask = ap.pixelfly_attention_block_mask(s, s, cfg, causal=causal)
    sched = ap.block_schedule(mask, blk, blk)
    q, k, v = _mk(b, h, s, d)
    o_ref = ref.block_sparse_attention_ref(
        q, k, v, mask, block_q=blk, block_k=blk, causal=causal
    )
    o_pal = ops.block_sparse_attention(
        q, k, v, sched, causal=causal, impl="interpret"
    )
    np.testing.assert_allclose(
        np.asarray(o_pal), np.asarray(o_ref), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("sq,sk,chunk", [(192, 192, 128), (140, 140, 128), (8, 200, 64)])
def test_flash_jnp_non_multiple_chunk(sq, sk, chunk):
    """Regression: flash_attention_jnp used to assert when the KV length
    was not a multiple of ``attn_chunk`` (non-power-of-two serving
    buckets, e.g. 192 with chunk 128). Padded chunks must be masked, not
    fatal."""
    import jax

    from repro.models import layers as L

    rng = np.random.default_rng(0)
    hk, g, d = 2, 2, 32
    q = jnp.asarray(rng.standard_normal((2, sq, hk, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, sk, hk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, sk, hk, d)), jnp.float32)
    got = L.flash_attention_jnp(
        q, k, v, causal=True, chunk=chunk, sm_scale=d ** -0.5
    )
    # dense reference with the same grouped layout
    s = L._grouped_logits(q, k) * d ** -0.5
    mask = np.arange(sk)[None, :] <= np.arange(sq)[:, None]
    s = jnp.where(jnp.asarray(mask)[None, None, None], s, -jnp.inf)
    want = L._grouped_out(jax.nn.softmax(s, axis=-1), v)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_full_mask_equals_dense_attention():
    """With every block scheduled, block-sparse attention == dense."""
    b, h, s, d, blk = 2, 2, 256, 64, 64
    mask = np.ones((s // blk, s // blk), dtype=bool)
    sched = ap.block_schedule(mask, blk, blk)
    q, k, v = _mk(b, h, s, d)
    o_dense = ref.dense_attention_ref(q, k, v, causal=True)
    o_pal = ops.block_sparse_attention(
        q, k, v, sched, causal=True, impl="interpret"
    )
    np.testing.assert_allclose(
        np.asarray(o_pal), np.asarray(o_dense), rtol=2e-4, atol=2e-4
    )


def test_bf16_path():
    b, h, s, d, blk = 1, 2, 256, 64, 64
    cfg = ap.AttentionPatternConfig(block=blk)
    mask = ap.pixelfly_attention_block_mask(s, s, cfg, causal=True)
    sched = ap.block_schedule(mask, blk, blk)
    q, k, v = _mk(b, h, s, d, dtype=jnp.bfloat16)
    try:
        o_ref = ref.block_sparse_attention_ref(
            q, k, v, mask, block_q=blk, block_k=blk, causal=True
        )
        o_pal = ops.block_sparse_attention(
            q, k, v, sched, causal=True, impl="interpret"
        )
        o_pal.block_until_ready()
    except Exception as e:
        if "Unsupported element type" in str(e):
            pytest.skip("CPU backend cannot execute bf16 dot (compile-only ok)")
        raise
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_schedule_covers_mask():
    cfg = ap.AttentionPatternConfig(block=64, local_blocks=2, global_blocks=1)
    mask = ap.pixelfly_attention_block_mask(1024, 1024, cfg, causal=True)
    sched = ap.block_schedule(mask, 64, 64)
    rebuilt = np.zeros_like(mask)
    for i in range(sched.nqb):
        for t in range(sched.max_nkv):
            if sched.valid[i, t]:
                rebuilt[i, sched.kv_index[i, t]] = True
    assert np.array_equal(rebuilt, mask)


def test_keys_per_query_subquadratic():
    """O(b log n) keys/query: doubling n adds one stride, not 2x keys."""
    cfg = ap.AttentionPatternConfig(block=128)
    k1 = ap.keys_per_query(
        ap.pixelfly_attention_block_mask(4096, 4096, cfg), 128, 4096
    )
    k2 = ap.keys_per_query(
        ap.pixelfly_attention_block_mask(8192, 8192, cfg), 128, 8192
    )
    assert k2 < 1.5 * k1  # far below the 2x of dense attention
