"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import attn_pattern as ap
from repro.core import butterfly as bf
from repro.core.pixelfly import LinearSpec, apply_linear, init_linear
from repro.kernels import ref


@given(
    nb=st.sampled_from([2, 4, 8, 16, 32, 64]),
    ts=st.integers(0, 5),
)
@settings(max_examples=40, deadline=None)
def test_square_slots_are_involutive_permutations(nb, ts):
    """Every stride slot of a square flat butterfly is a self-inverse
    permutation of block rows (i -> i^s) — the algebraic property that
    makes the transposed pattern identical to the forward one."""
    k = min(1 << (ts + 1), nb)
    cols = bf.flat_butterfly_cols(nb, nb, k)
    for t in range(1, cols.shape[1]):
        perm = cols[:, t]
        assert sorted(perm) == list(range(nb))  # permutation
        assert all(perm[perm[i]] == i for i in range(nb))  # involution


@given(
    seq=st.sampled_from([256, 512, 1024, 2048]),
    local=st.integers(1, 3),
    glob=st.integers(0, 2),
)
@settings(max_examples=30, deadline=None)
def test_causal_pattern_always_covers_self_and_past_anchor(seq, local, glob):
    """Causal pixelfly attention: every query block attends to its own
    (diagonal) block, and the schedule never references a future block."""
    cfg = ap.AttentionPatternConfig(
        block=128, local_blocks=local, global_blocks=glob
    )
    mask = ap.pixelfly_attention_block_mask(seq, seq, cfg, causal=True)
    n = mask.shape[0]
    for i in range(n):
        assert mask[i, i], "diagonal block must be attended"
        assert not mask[i, i + 1 :].any(), "future blocks must be masked"


@given(
    seq=st.sampled_from([256, 512, 1024]),
)
@settings(max_examples=12, deadline=None)
def test_schedule_roundtrip(seq):
    cfg = ap.AttentionPatternConfig(block=128)
    mask = ap.pixelfly_attention_block_mask(seq, seq, cfg, causal=True)
    sched = ap.block_schedule(mask, 128, 128)
    # schedule rows are exactly the mask rows
    for i in range(sched.nqb):
        want = set(np.nonzero(mask[i])[0].tolist())
        got = {
            int(sched.kv_index[i, t])
            for t in range(sched.max_nkv)
            if sched.valid[i, t]
        }
        assert got == want


@given(
    bi=st.sampled_from([128, 256, 384]),
    bo=st.sampled_from([128, 256, 512]),
    density=st.floats(0.1, 0.9),
    seed=st.integers(0, 3),
)
@settings(max_examples=20, deadline=None)
def test_pixelfly_linear_linearity(bi, bo, density, seed):
    """The layer is linear: f(ax + by) == a f(x) + b f(y)."""
    spec = LinearSpec.pixelfly(bi, bo, density, block=64, dtype=jnp.float32)
    params = init_linear(jax.random.PRNGKey(seed), spec)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, bi)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((3, bi)), jnp.float32)
    lhs = apply_linear(spec, params, 2.0 * x - 0.5 * y)
    rhs = 2.0 * apply_linear(spec, params, x) - 0.5 * apply_linear(spec, params, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=2e-3, atol=2e-3)


@given(
    n=st.sampled_from([256, 512]),
    k=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 5),
)
@settings(max_examples=20, deadline=None)
def test_bsr_equals_dense_of_scattered_weight(n, k, seed):
    """bsr(x) == x @ dense(W) for the scattered weight, any stride/seed."""
    rng = np.random.default_rng(seed)
    pat = bf.make_pattern(n, n, block=64, max_stride=k)
    blocks = jnp.asarray(
        rng.standard_normal((pat.nb_out, pat.r, 64, 64)), jnp.float32
    )
    cols = jnp.asarray(pat.cols)
    x = jnp.asarray(rng.standard_normal((4, n)), jnp.float32)
    w = ref.bsr_to_dense(blocks, cols, n)
    np.testing.assert_allclose(
        np.asarray(ref.bsr_matmul_gather(x, blocks, cols)),
        np.asarray(x @ w),
        rtol=2e-4, atol=2e-4,
    )


@given(data_bytes=st.integers(1, 3))
@settings(max_examples=3, deadline=None)
def test_checkpoint_roundtrip_random_trees(data_bytes):
    import tempfile

    from repro.training import checkpoint as ck

    rng = np.random.default_rng(data_bytes)
    tree = {
        "a": jnp.asarray(rng.standard_normal((4, data_bytes * 8))),
        "nested": {"b": jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, tree)
        out, _ = ck.restore(d, jax.tree.map(jnp.zeros_like, tree))
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
