"""MoE: routing invariants, capacity, shared experts, load-balance loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib


def _cfg(**kw):
    base = dict(
        name="t", family="moe", num_layers=1, d_model=64, num_heads=0,
        num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=64,
        moe_num_experts=8, moe_top_k=2, moe_num_shared=0, moe_d_ff=32,
        moe_capacity_factor=8.0, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def _run(cfg, x, seed=0):
    spec = moe_lib.MoeSpec(cfg)
    params = moe_lib.init_moe(jax.random.PRNGKey(seed), spec)
    return moe_lib.apply_moe(spec, params, x), params, spec


def test_output_finite_and_shaped():
    cfg = _cfg()
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 64)), jnp.float32)
    (y, aux), _, _ = _run(cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["lb_loss"]) > 0


def test_topk_full_equals_weighted_sum_of_experts():
    """With top_k == E and huge capacity, the sort-based dispatch must equal
    the dense 'every expert on every token, probability-weighted' oracle."""
    cfg = _cfg(moe_top_k=8, moe_capacity_factor=16.0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    (y, _), params, spec = _run(cfg, x)

    logits = np.asarray(x.reshape(8, 64) @ np.asarray(params["router"]))
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    xs = x.reshape(8, 64)
    ys = np.zeros((8, 64), np.float32)
    for e in range(8):
        h = np.asarray(xs) @ np.asarray(params["wg"][e])
        u = np.asarray(xs) @ np.asarray(params["wu"][e])
        o = (jax.nn.silu(jnp.asarray(h)) * u) @ np.asarray(params["wd"][e])
        ys += np.asarray(probs[:, e : e + 1]) * np.asarray(o)
    np.testing.assert_allclose(
        np.asarray(y.reshape(8, 64)), ys, rtol=2e-3, atol=2e-3
    )


def test_capacity_drops_tokens():
    """Tiny capacity factor must drop tokens (outputs partially zeroed),
    never crash or produce NaN."""
    cfg = _cfg(moe_capacity_factor=0.25)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32, 64)), jnp.float32)
    (y, _), _, _ = _run(cfg, x)
    assert np.isfinite(np.asarray(y)).all()


def test_shared_experts_add():
    cfg0 = _cfg(moe_num_shared=0)
    cfg2 = _cfg(moe_num_shared=2)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 8, 64)), jnp.float32)
    (_, _), p0, _ = _run(cfg0, x)
    (_, _), p2, _ = _run(cfg2, x)
    assert "shared" not in p0 and "shared" in p2


def test_routing_groups_consistent():
    """Group-local routing must give the same result as one group when the
    capacity is unconstrained (routing decisions are per-token)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 8, 64)), jnp.float32)
    cfg1 = _cfg(moe_routing_groups=1, moe_capacity_factor=16.0)
    cfg4 = _cfg(moe_routing_groups=4, moe_capacity_factor=16.0)
    spec1, spec4 = moe_lib.MoeSpec(cfg1), moe_lib.MoeSpec(cfg4)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), spec1)
    y1, _ = moe_lib.apply_moe(spec1, params, x)
    y4, _ = moe_lib.apply_moe(spec4, params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=2e-4, atol=2e-4)


def test_sparse_experts():
    cfg = _cfg(sparse=True, sparse_density=0.6, sparse_block=16, d_model=64, moe_d_ff=64)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 8, 64)), jnp.float32)
    (y, _), _, _ = _run(cfg, x)
    assert np.isfinite(np.asarray(y)).all()
