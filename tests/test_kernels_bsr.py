"""Pallas BSR matmul vs pure-jnp oracle: shape/dtype sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import butterfly as bf
from repro.kernels import ops, ref

CASES = [
    # (batch, n_in, n_out, block, max_stride)
    (8, 256, 256, 64, 2),
    (16, 512, 512, 128, 4),
    (32, 256, 512, 64, 4),
    (8, 512, 256, 128, 2),
    (7, 384, 256, 128, 2),   # ragged batch (padding path)
    (4, 256, 1024, 128, 8),
]


def _mk(case, dtype, seed=0):
    b_, n_in, n_out, blk, k = case
    rng = np.random.default_rng(seed)
    pat = bf.make_pattern(n_out, n_in, block=blk, max_stride=k)
    blocks = jnp.asarray(
        rng.standard_normal((pat.nb_out, pat.r, blk, blk)) / np.sqrt(pat.r * blk),
        dtype,
    )
    x = jnp.asarray(rng.standard_normal((b_, n_in)), dtype)
    return x, blocks, jnp.asarray(pat.cols)


@pytest.mark.parametrize("case", CASES)
def test_gather_matches_dense_mask(case):
    x, blocks, cols = _mk(case, jnp.float32)
    yg = ref.bsr_matmul_gather(x, blocks, cols)
    yd = ref.bsr_matmul_dense_mask(x, blocks, cols)
    np.testing.assert_allclose(yg, yd, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_interpret_matches_oracle(case, dtype):
    x, blocks, cols = _mk(case, dtype)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    try:
        y_ref = np.asarray(ref.bsr_matmul_gather(x, blocks, cols), np.float32)
        y_pal = np.asarray(
            ops.bsr_matmul(x, blocks, cols, impl="interpret"), np.float32
        )
    except Exception as e:  # XLA:CPU lacks some bf16xbf16->f32 dot thunks
        if "Unsupported element type" in str(e):
            pytest.skip("CPU backend cannot execute bf16 dot (compile-only ok)")
        raise
    np.testing.assert_allclose(y_pal, y_ref, rtol=tol, atol=tol)


def test_leading_dims_flattened():
    x, blocks, cols = _mk((8, 256, 256, 64, 2), jnp.float32)
    x3 = x.reshape(2, 4, 256)
    y3 = ops.bsr_matmul(x3, blocks, cols, impl="interpret")
    y2 = ops.bsr_matmul(x, blocks, cols, impl="interpret")
    np.testing.assert_allclose(
        np.asarray(y3).reshape(8, -1), np.asarray(y2), rtol=1e-5, atol=1e-5
    )


def test_duplicate_cols_sum():
    """Rectangular stretch can produce duplicate column slots; gather and
    dense-mask semantics must agree (duplicates sum)."""
    blk = 64
    cols = jnp.asarray(np.array([[0, 0], [1, 1]], np.int32))
    rng = np.random.default_rng(1)
    blocks = jnp.asarray(rng.standard_normal((2, 2, blk, blk)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 2 * blk)), jnp.float32)
    yg = ref.bsr_matmul_gather(x, blocks, cols)
    yd = ref.bsr_matmul_dense_mask(x, blocks, cols)
    np.testing.assert_allclose(yg, yd, rtol=1e-4, atol=1e-4)


def test_gradients_flow():
    x, blocks, cols = _mk((8, 256, 256, 64, 2), jnp.float32)

    def f(b_):
        return ref.bsr_matmul_gather(x, b_, cols).sum()

    g = jax.grad(f)(blocks)
    assert g.shape == blocks.shape
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).sum() > 0


def test_custom_vjp_matches_autodiff():
    """Scatter-free backward == jax.grad of the gather formulation."""
    x, blocks, cols = _mk((8, 256, 512, 64, 4), jnp.float32)
    cols_np = np.asarray(cols)

    def f_auto(x, b_):
        return (ref.bsr_matmul_gather(x, b_, cols) ** 2).sum()

    def f_custom(x, b_):
        return (ref.bsr_matmul_custom_vjp(x, b_, cols_np) ** 2).sum()

    y1, (gx1, gb1) = jax.value_and_grad(f_auto, argnums=(0, 1))(x, blocks)
    y2, (gx2, gb2) = jax.value_and_grad(f_custom, argnums=(0, 1))(x, blocks)
    np.testing.assert_allclose(y1, y2, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb1), np.asarray(gb2), rtol=1e-4, atol=1e-4)


def test_custom_vjp_rectangular_duplicates():
    """Transposed tables handle the duplicate columns of stretched
    rectangular patterns (ragged fan-in padding)."""
    x, blocks, cols = _mk((4, 256, 1024, 128, 8), jnp.float32)
    cols_np = np.asarray(cols)
    gx1 = jax.grad(lambda x: ref.bsr_matmul_gather(x, blocks, cols).sum())(x)
    gx2 = jax.grad(
        lambda x: ref.bsr_matmul_custom_vjp(x, blocks, cols_np).sum()
    )(x)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=1e-4, atol=1e-4)
