"""SLO-aware scheduling: priority queue, host-memory swap, preemption.

The load-bearing guarantee: preemption is a *pure scheduling change* —
a preempted-and-resumed sequence emits bit-identical tokens to an
unpreempted run (KV pages round-trip through host memory unchanged, and
the sampler's noise depends only on (seed, sample index), never on the
slot, step, or co-batch). Everything else — priority order, hysteresis,
shared-page pinning, structured rejections — is checked against the
engine's observable records.
"""

import time

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.serving import (
    REJECT_TIMEOUT,
    REJECT_TOO_LARGE,
    Engine,
    EngineConfig,
    PagedKVCache,
    Request,
    SamplingParams,
    ScheduleParams,
    Scheduler,
    SwapManager,
)


def _smoke_cfg(**kw):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=2, vocab_size=128, **kw
    )


def _mesh():
    return make_local_mesh()


# ----------------------------------------------------------------------
# ScheduleParams / priority queue (no model)
# ----------------------------------------------------------------------


def test_schedule_params_validation():
    with pytest.raises(ValueError):
        ScheduleParams(deadline_s=0.0)
    with pytest.raises(ValueError):
        ScheduleParams(deadline_s=-1.0)
    with pytest.raises(ValueError):
        ScheduleParams(max_queue_wait_s=-0.1)
    with pytest.raises(TypeError):
        Request(1, np.array([1]), 1, schedule="high")  # type: ignore


def test_scheduler_orders_by_priority_then_deadline_then_fcfs():
    sch = Scheduler(1)
    prompt = np.array([1, 2, 3])
    lo_late = Request(1, prompt, 1)  # priority 0, no deadline
    lo_soon = Request(
        2, prompt, 1,
        schedule=ScheduleParams(deadline_s=1.0), submit_s=0.0,
    )
    hi = Request(3, prompt, 1, schedule=ScheduleParams(priority=2))
    lo_later = Request(
        4, prompt, 1,
        schedule=ScheduleParams(deadline_s=9.0), submit_s=0.0,
    )
    for r in (lo_late, lo_soon, hi, lo_later):
        sch.submit(r)
    # priority first; EDF within the class; deadline-less FCFS last
    assert [r.uid for r in sch.peek_admissible(4)] == [3, 2, 4, 1]
    # admit() pops the head; admit(request=) pops mid-queue
    assert sch.admit(0).request.uid == 3
    sch.evict(0)
    assert sch.admit(1, request=lo_later).request.uid == 4
    assert [r.uid for r in sch.waiting] == [2, 1]


def test_scheduler_resume_rebinds_preserved_state():
    sch = Scheduler(2)
    req = Request(1, np.array([1, 2, 3]), 4)
    sch.submit(req)
    st = sch.admit(0)
    st.generated.extend([5, 6])
    st.pos = 5
    # preempt: slot freed, request re-queued (front of its class)
    sch.evict(st.slot)
    sch.submit(req)
    other = Request(2, np.array([7]), 1)
    sch.submit(other)
    assert sch.peek_admissible(2)[0] is req  # older uid leads the class
    back = sch.resume(st, request=req)
    assert back is st and sch.slots[back.slot] is st
    assert back.generated == [5, 6] and back.pos == 5
    assert req not in sch.waiting
    # no free slot -> resume refuses (and leaves the queue untouched)
    sch.admit(1)
    sch.evict(back.slot)
    sch.submit(req)
    third = Request(3, np.array([8]), 1)
    sch.submit(third)
    sch.admit(2, request=third)
    assert sch.resume(st, request=req) is None
    assert req in sch.waiting


# ----------------------------------------------------------------------
# SwapManager (real device buffers, no model forward)
# ----------------------------------------------------------------------


def test_swap_manager_roundtrip_restores_page_bytes():
    cfg = _smoke_cfg().replace(
        num_layers=1, num_heads=2, num_kv_heads=1, head_dim=8,
        attn_block=4,
    )
    kv = PagedKVCache(cfg, max_slots=2, max_len=16)
    sm = SwapManager(kv)
    kv.alloc_upto(0, 11)  # 3 pages
    pages = kv.owned_pages(0)
    # stamp each page with a distinct constant so restores are provable
    for p in pages:
        kv.buffers = jax.tree.map(
            lambda b, p=p: b.at[:, p].set(float(p)), kv.buffers
        )
    rec = sm.swap_out(0)  # nothing shared: everything goes to host
    assert rec.pin_pages == [] and rec.n_host == 3
    assert kv.pages_owned(0) == 0 and kv.free_pages == kv.n_pages - 1
    sm.finalize(rec)
    assert not rec.pending
    # churn the freed pages so a stale-device-alias bug would show
    kv.alloc_upto(1, 15)
    kv.buffers = jax.tree.map(lambda b: b.at[:, 1:].set(-1.0), kv.buffers)
    kv.free_slot(1)
    # resume into the other slot: all pages come from the host copy
    kv.alloc_upto(1, 11)
    sm.swap_in(rec, 1, n_resident=0)
    new_pages = kv.owned_pages(1)
    for old, new in zip(pages, new_pages):
        for leaf in jax.tree.leaves(kv.buffers):
            np.testing.assert_array_equal(
                np.asarray(leaf[:, new]), float(old)
            )
    assert sm.stats.out_pages == 3 and sm.stats.in_pages == 3


def test_swap_manager_pins_shared_prefix_instead_of_copying():
    cfg = _smoke_cfg().replace(
        num_layers=1, num_heads=2, num_kv_heads=1, head_dim=8,
        attn_block=4,
    )
    kv = PagedKVCache(cfg, max_slots=2, max_len=16)
    sm = SwapManager(kv)
    kv.alloc_upto(0, 11)  # 3 pages
    shared = kv.owned_pages(0)[:2]
    for p in shared:
        kv.incref(p)
    kv.adopt(1, shared)  # slot 1 shares the 2-page prefix
    rec = sm.swap_out(0, max_pin=2)
    # shared pages pinned in place (never copied), private page to host
    assert rec.pin_pages == shared and rec.n_host == 1
    for p in shared:  # slot 1's ref + the record's pin
        assert kv.refcount(p) == 2
    # resume: the re-match recovers the pinned prefix, host covers the rest
    for p in shared:
        kv.incref(p)
    kv.adopt(0, list(shared))
    kv.alloc_upto(0, 11)
    sm.swap_in(rec, 0, n_resident=2)
    for p in shared:  # record pin released; two slots own it
        assert kv.refcount(p) == 2
    assert sm.stats.pinned_pages == 2 and sm.stats.out_pages == 1
    # a re-match that cannot cover the pinned prefix is a hard error
    # (slot 0 still holds the shared pages, so both get pinned again)
    rec2 = sm.swap_out(1, max_pin=2)
    assert rec2.pin_pages == shared and rec2.n_host == 0
    with pytest.raises(ValueError):
        sm.swap_in(rec2, 1, n_resident=0)
    sm.discard(rec2)
    for p in shared:  # only slot 0's reference survives the discard
        assert kv.refcount(p) == 1


# ----------------------------------------------------------------------
# Engine-level preemption
# ----------------------------------------------------------------------


def test_preempted_streams_bit_exact_greedy_and_sampled():
    """The ISSUE's core contract: a preempted+resumed request's tokens
    are bit-identical to an unpreempted run — greedy AND seeded
    sampling (the noise stream is indexed by (seed, sample index), so a
    swap round trip cannot shift it)."""
    cfg = _smoke_cfg()
    mesh = _mesh()
    page = cfg.attn_block
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, 127, size=n).astype(np.int32)
        for n in (page + 3, page + 5, 7)
    ]
    sampled = SamplingParams(temperature=0.8, top_k=20, seed=7)

    def serve(preemption: bool, n_pages: int):
        eng = Engine(
            cfg,
            mesh,
            engine_cfg=EngineConfig(
                max_slots=2,
                max_len=4 * page,
                n_pages=n_pages,
                prefix_cache=True,
                preemption=preemption,
                preempt_min_steps=2,
            ),
        )
        uids = [
            eng.submit(prompts[0], 2 * page, sampling=sampled),
            eng.submit(prompts[1], 2 * page),
        ]
        fins = []
        if preemption:
            for _ in range(4):  # let the pool fill before the VIP lands
                fins += eng.step()
        uids.append(
            eng.submit(
                prompts[2], page,
                schedule=ScheduleParams(priority=5, deadline_s=60.0),
            )
        )
        fins += eng.drain(max_steps=800)
        return uids, {f.uid: f for f in fins}, eng

    base_uids, base, _ = serve(False, 0)
    # a 5-page pool around 2 slots x (2..4)-page lifetimes forces the
    # high-priority submit to preempt instead of waiting
    got_uids, got, eng = serve(True, 5)
    assert sum(f.preemptions for f in got.values()) >= 1
    s = eng.stats_summary()
    assert s["preemption"]["swap_outs"] >= 1
    assert s["preemption"]["out_bytes"] > 0
    assert s["preemption"]["swap_ins"] == s["preemption"]["swap_outs"]
    for ub, ug in zip(base_uids, got_uids):
        np.testing.assert_array_equal(base[ub].tokens, got[ug].tokens)
    # the preempted request's record carries its preemption count + SLO
    vip = got[got_uids[2]]
    assert vip.schedule.priority == 5 and vip.slo_met is True
    assert vip.ttft_s is not None and vip.e2e_s >= vip.ttft_s


def test_priority_request_preempts_full_pool():
    """Starvation check: a deadline'd high-priority request submitted
    against a full pool of long-running decodes swaps its way in and
    finishes long before the background does."""
    cfg = _smoke_cfg()
    mesh = _mesh()
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=128),
    )
    rng = np.random.default_rng(1)
    bg = [
        eng.submit(rng.integers(1, 127, 8).astype(np.int32), 60)
        for _ in range(2)
    ]
    fins = []
    for _ in range(6):
        fins += eng.step()
    hi = eng.submit(
        rng.integers(1, 127, 8).astype(np.int32),
        4,
        schedule=ScheduleParams(priority=3, deadline_s=120.0),
    )
    fins += eng.drain(max_steps=500)
    by_uid = {f.uid: f for f in fins}
    assert eng.stats.preemptions >= 1
    assert all(
        by_uid[hi].finish_step < by_uid[b].finish_step for b in bg
    )
    # the victim resumed and still emitted its full 60 tokens
    assert all(len(by_uid[b].tokens) == 60 for b in bg)
    # equal priority never preempts: refill the pool, submit a peer
    pre = eng.stats.preemptions
    for _ in range(2):
        eng.submit(rng.integers(1, 127, 8).astype(np.int32), 30)
    for _ in range(6):
        eng.step()
    eng.submit(rng.integers(1, 127, 8).astype(np.int32), 4)
    eng.drain(max_steps=500)
    assert eng.stats.preemptions == pre
    # page conservation after all the swap traffic
    kv = eng.kv
    assert kv.free_pages + kv.cached_pages == kv.n_pages - 1
    assert (kv._ref[1:] == 0).sum() == kv.n_pages - 1


def test_hysteresis_blocks_preemption_of_fresh_sequences():
    cfg = _smoke_cfg()
    mesh = _mesh()
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(
            max_slots=2, max_len=128, preempt_min_steps=10_000
        ),
    )
    rng = np.random.default_rng(2)
    for _ in range(2):
        eng.submit(rng.integers(1, 127, 8).astype(np.int32), 20)
    for _ in range(6):
        eng.step()
    eng.submit(
        rng.integers(1, 127, 8).astype(np.int32),
        4,
        schedule=ScheduleParams(priority=9),
    )
    fins = eng.drain(max_steps=300)
    # nothing ran long enough to be victimized: the VIP waited instead
    assert eng.stats.preemptions == 0
    assert all(f.preemptions == 0 for f in fins)


def test_preemption_pins_shared_prefix_pages():
    """A victim sharing its prompt prefix with a running peer must not
    copy those pages to host — they stay pinned in place."""
    cfg = _smoke_cfg()
    mesh = _mesh()
    page = cfg.attn_block
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(
            max_slots=2, max_len=4 * page, prefix_cache=True,
            preempt_min_steps=2,
        ),
    )
    rng = np.random.default_rng(3)
    shared = rng.integers(1, 127, 2 * page + 5).astype(np.int32)
    a = eng.submit(shared, page)
    eng.step()  # admit + index A's prompt pages
    b = eng.submit(shared, 2 * page)  # same prompt: shares 2 pages
    for _ in range(4):
        eng.step()
    hi = eng.submit(
        rng.integers(1, 127, 7).astype(np.int32),
        4,
        schedule=ScheduleParams(priority=7),
    )
    fins = eng.drain(max_steps=800)
    by_uid = {f.uid: f for f in fins}
    s = eng.stats_summary()["preemption"]
    assert s["swap_outs"] >= 1
    # the victim's 2-page shared prefix was pinned, never copied
    assert s["pinned_pages"] >= 2
    # identical prompts + greedy: identical streams regardless of which
    # one was preempted
    n = min(len(by_uid[a].tokens), len(by_uid[b].tokens))
    np.testing.assert_array_equal(
        by_uid[a].tokens[:n], by_uid[b].tokens[:n]
    )


# ----------------------------------------------------------------------
# Structured rejections
# ----------------------------------------------------------------------


def test_structured_rejections_and_drain_delivery():
    cfg = _smoke_cfg()
    mesh = _mesh()
    page = cfg.attn_block
    eng = Engine(
        cfg,
        mesh,
        engine_cfg=EngineConfig(max_slots=2, max_len=2 * page),
    )
    rng = np.random.default_rng(4)

    # too-large prompt: rejected, not raised — even with an idle queue,
    # drain() must deliver it
    big = rng.integers(1, 127, 5 * page).astype(np.int32)
    uid = eng.submit(big, 2)
    out = eng.drain(max_steps=5)
    assert [f.uid for f in out] == [uid]
    assert out[0].rejected and out[0].reject_reason == REJECT_TOO_LARGE
    assert out[0].finish_reason == "rejected"
    assert out[0].slo_met is None  # no deadline attached
    assert len(out[0].tokens) == 0

    # an oversized *generation* budget is NOT a rejection: the engine
    # caps the lifetime at slot capacity and finishes on "capacity"
    uid2 = eng.submit(rng.integers(1, 127, 4).astype(np.int32), 10**6)
    out2 = eng.drain(max_steps=300)
    assert out2[0].uid == uid2
    assert out2[0].finish_reason == "capacity"
    # prefill emits token 0, then one decode per write position
    # plen..max_len-1: 1 + (max_len - plen) tokens total
    assert len(out2[0].tokens) == 2 * page - 4 + 1

    # queue-wait timeout: a full pool + an impatient request
    bg = [
        eng.submit(rng.integers(1, 127, 8).astype(np.int32), 40)
        for _ in range(2)
    ]
    eng.step()
    impatient = eng.submit(
        rng.integers(1, 127, 8).astype(np.int32),
        4,
        schedule=ScheduleParams(
            max_queue_wait_s=0.0, deadline_s=5.0
        ),
    )
    time.sleep(0.01)
    fins = eng.drain(max_steps=300)
    by_uid = {f.uid: f for f in fins}
    rej = by_uid[impatient]
    assert rej.rejected and rej.reject_reason == REJECT_TIMEOUT
    assert rej.slo_met is False  # deadline'd + rejected = missed
    assert all(len(by_uid[b].tokens) == 40 for b in bg)
    s = eng.stats_summary()
    assert s["rejected"]["total"] == 2
    assert s["rejected"][REJECT_TOO_LARGE] == 1
    assert s["rejected"][REJECT_TIMEOUT] == 1
    assert s["slo"] == {
        "with_deadline": 1, "met": 0, "attainment": 0.0
    }
