"""Runtime dispatch guards: the steady-state decode loop must run under
DispatchGuard with zero recompiles and zero implicit device->host
transfers per step — and an injected violation must trip it."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards
from repro.analysis.guards import DispatchGuard, HostSyncError, RecompileError
from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.serving import Engine, EngineConfig


def _smoke_cfg(**kw):
    return registry.get_smoke("qwen3-1.7b").replace(
        num_layers=2, vocab_size=128, **kw
    )


# ----------------------------------------------------------------------
# guard mechanics (no engine)
# ----------------------------------------------------------------------


def test_guard_trips_on_implicit_syncs():
    x = jnp.arange(4.0)
    with DispatchGuard(max_compiles=None) as g:
        host = jax.device_get(x)  # the sanctioned explicit channel
        assert isinstance(host, np.ndarray)
        with pytest.raises(HostSyncError):
            x[0].item()
        with pytest.raises(HostSyncError):
            int(x[1])
        with pytest.raises(HostSyncError):
            bool(x[0] > 0)
    assert g.explicit_syncs == 1
    assert g.implicit_syncs == 3
    # interception is fully unwound on exit
    assert x[0].item() == 0.0 and int(x[1]) == 1


def test_guard_counts_without_raising_when_asked():
    x = jnp.ones((2,))
    with DispatchGuard(max_compiles=None, raise_on_sync=False) as g:
        x[0].item()
        float(x[1])
    assert g.implicit_syncs == 2


def test_guard_trips_on_recompile():
    f = jax.jit(lambda a: a * 3)
    f(jnp.ones((4,))).block_until_ready()  # warm
    with pytest.raises(RecompileError):
        with DispatchGuard(max_compiles=0):
            # fresh shape -> fresh program -> backend compile
            f(jnp.ones((5,))).block_until_ready()


def test_guard_passes_warm_cache_hits():
    f = jax.jit(lambda a: a + 1)
    f(jnp.ones((3,))).block_until_ready()
    with DispatchGuard(max_compiles=0) as g:
        y = f(jnp.ones((3,)))
        jax.device_get(y)
    assert g.compiles == 0 and g.implicit_syncs == 0


def test_hot_path_marker_is_inert():
    @guards.hot_path
    def fn(x):
        return x + 1

    assert guards.is_hot_path(fn)
    assert fn(1) == 2


# ----------------------------------------------------------------------
# the tier-1 guarantee: steady-state decode is guard-clean
# ----------------------------------------------------------------------


def _warmed_engine(n_reqs=3, max_new=32):
    cfg = _smoke_cfg()
    eng = Engine(
        cfg,
        make_local_mesh(),
        engine_cfg=EngineConfig(max_slots=4, max_len=128),
    )
    rng = np.random.default_rng(11)
    for _ in range(n_reqs):
        prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
        eng.submit(prompt, max_new)
    # warmup: admission (prefill compile + sync) and the first decode
    fins = eng.step()
    assert not fins and len(eng.scheduler.active()) == n_reqs
    return eng


def test_steady_state_decode_is_guard_clean():
    eng = _warmed_engine()
    n_steps = 6
    with DispatchGuard(max_compiles=0) as g:
        for _ in range(n_steps):
            fins = eng.step()
            assert not fins  # steady state: nobody finishes mid-guard
    assert g.compiles == 0, "decode recompiled after warmup"
    assert g.implicit_syncs == 0
    # exactly one batched fetch (the next-token row) per decode step
    assert g.explicit_syncs == n_steps
    # the engine is still healthy afterwards: drain to completion
    fins = eng.drain(max_steps=200)
    assert len(fins) == 3


def test_injected_item_trips_the_guard():
    eng = _warmed_engine()
    orig = eng._decode

    def leaky_decode(*args):
        toks_dev, buffers = orig(*args)
        toks_dev[0].item()  # the classic per-step scalar pull
        return toks_dev, buffers

    eng._decode = leaky_decode
    with pytest.raises(HostSyncError, match="item"):
        with DispatchGuard(max_compiles=0):
            eng.step()
    # (no recovery assertion: the aborted step already donated the KV
    # buffers — the guard's contract is to fail loudly, not to resume)


def test_injected_recompile_trips_the_guard():
    eng = _warmed_engine()
    orig = eng._decode

    def recompiling_decode(*args):
        # a fresh jit wrapper per call: always a cache miss
        return jax.jit(lambda p, b, t, pos, tab: orig(p, b, t, pos, tab))(
            *args
        )

    eng._decode = recompiling_decode
    with pytest.raises(RecompileError):
        with DispatchGuard(max_compiles=0):
            eng.step()
    eng._decode = orig
