"""Per-arch smoke tests (assignment (f)): reduced same-family config, one
forward/train step on CPU, asserting shapes + finiteness; plus a decode
step through the cache path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as T


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.embed_inputs:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32
        )
    else:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)) * 0.1, jnp.float32
        )
        if cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :, None],
                (b, s, len(cfg.mrope_sections)),
            )
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
@pytest.mark.parametrize("sparse", [False, True])
def test_smoke_forward_train(arch, sparse):
    cfg = registry.get_smoke(arch, sparse=sparse)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = T.forward_train(cfg, params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["nll"]))


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_smoke_decode(arch):
    cfg = registry.get_smoke(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    b = 2
    caches = T.init_cache(cfg, b, 64)
    logits, caches2 = T.decode_step(
        cfg, params, caches, jnp.zeros((b,), jnp.int32), jnp.asarray(3, jnp.int32)
    )
    assert logits.shape == (b, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_smoke_prefill(arch):
    cfg = registry.get_smoke(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, caches = T.prefill(cfg, params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published numbers."""
    c = registry.get("deepseek-67b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    c = registry.get("qwen3-1.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.qk_norm) == (28, 2048, 16, 8, 6144, 151936, True)
    c = registry.get("qwen2-1.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == (28, 1536, 12, 2, 8960, 151936, True)
    c = registry.get("smollm-360m")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 960, 15, 5, 2560, 49152)
    c = registry.get("qwen2-vl-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.mrope_sections) == (
        28, 3584, 28, 4, 18944, 152064, (16, 24, 24))
    c = registry.get("deepseek-moe-16b")
    assert (c.num_layers, c.d_model, c.moe_num_experts, c.moe_top_k,
            c.moe_num_shared, c.moe_d_ff, c.vocab_size) == (
        28, 2048, 64, 6, 2, 1408, 102400)
    c = registry.get("kimi-k2-1t-a32b")
    assert (c.num_layers, c.d_model, c.moe_num_experts, c.moe_top_k,
            c.vocab_size) == (61, 7168, 384, 8, 163840)
    c = registry.get("musicgen-large")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == (
        48, 2048, 32, 8192, 2048)
    c = registry.get("zamba2-2.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size,
            c.ssm_state) == (54, 2560, 32, 10240, 32000, 64)
    c = registry.get("mamba2-130m")
    assert (c.num_layers, c.d_model, c.vocab_size, c.ssm_state) == (
        24, 768, 50280, 128)


def test_layer_groups_cover_depth():
    for arch in registry.ARCH_NAMES:
        cfg = registry.get(arch)
        total = sum(g.count for g in cfg.layer_groups())
        assert total == cfg.num_layers, arch


def test_zamba_shares_attention_params():
    cfg = registry.get_smoke("zamba2-2.7b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    keys = [g.param_key for g in cfg.layer_groups() if g.kind == "shared_attn"]
    assert len(keys) >= 2 and len(set(keys)) == 1  # one shared subtree
    assert "shared_attn" in params["groups"]
