"""End-to-end system behaviour: the full lifecycle a production run sees —
train, checkpoint, preempt, ELASTIC restart on a different mesh layout,
continue training, then serve from the trained weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Server
from repro.training.data import SyntheticLM
from repro.training.loop import TrainConfig, Trainer
from repro.training.optimizer import OptConfig


def _mk(tmp_path, mesh, steps=40):
    cfg = registry.get_smoke("qwen3-1.7b", sparse=True).replace(
        num_layers=2, vocab_size=128
    )
    data = SyntheticLM(128, 32, 4, seed=0)
    return Trainer(
        cfg,
        OptConfig(lr=5e-3, warmup_steps=2, total_steps=steps),
        data,
        mesh,
        TrainConfig(steps=steps, ckpt_dir=str(tmp_path), ckpt_every=100,
                    log_every=1000),
    )


@pytest.mark.slow
def test_full_lifecycle(tmp_path):
    d = tmp_path / "run"
    # phase 1: train on a (1, 1) data x model mesh, then "preempt"
    t1 = _mk(d, make_local_mesh(data=1, model=1))
    h1 = t1.run(12)
    t1._on_preempt(None, None)
    t1.run(5)  # stops immediately + checkpoints
    from repro.training import checkpoint as ck
    assert ck.latest_step(str(d)) == 12

    # phase 2: ELASTIC restart on a different mesh layout (model axis used)
    t2 = _mk(d, make_local_mesh(data=1, model=1))
    assert t2.step == 12
    h2 = t2.run(10)
    # training continues downward overall
    assert np.mean([h["loss"] for h in h2[-3:]]) < h1[0]["loss"]

    # phase 3: serve from the trained parameters
    cfg = t2.model_cfg
    server = Server(cfg, t2.mesh)
    server.params = t2.state["params"]
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(2, 8), dtype=np.int32
    )
    out = server.generate(prompts, gen_len=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_walker_agrees_with_xla_on_loop_free_programs():
    """Property: on programs without loops, the HLO walker's FLOPs match
    XLA's own cost_analysis (the walker only *adds* trip-count awareness)."""
    from repro.analysis import roofline

    rng = np.random.default_rng(0)
    for m, k, n in [(64, 32, 16), (128, 128, 128), (96, 256, 32)]:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        c = jax.jit(lambda a, b: (a @ b)).lower(a, b).compile()
        walker = roofline.analyze_hlo(c.as_text()).flops
        ca = c.cost_analysis() or {}
        if isinstance(ca, list):  # jax 0.4.x: one dict per program
            ca = ca[0] if ca else {}
        xla = ca.get("flops", 0.0)
        assert abs(walker - xla) <= 0.02 * max(walker, xla) + 1, (m, k, n)


def test_budget_allocation_end_to_end():
    """§3.3 rule of thumb: every layer type gets density ~= the global
    budget; the realized model density is within tolerance of the ask."""
    from repro.analysis.roofline import active_params

    for density in [0.15, 0.3]:
        cfg_s = registry.get("qwen3-1.7b", sparse=True, density=density)
        cfg_d = registry.get("qwen3-1.7b")
        ratio = active_params(cfg_s) / active_params(cfg_d)
        assert density * 0.5 < ratio < density * 2.0 + 0.1, (density, ratio)
