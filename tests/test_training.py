"""Training loop integration: convergence, checkpoint/resume, preemption,
straggler watchdog, gradient compression."""

import os
import signal

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.mesh import make_local_mesh
from repro.training.data import SyntheticLM
from repro.training.loop import TrainConfig, Trainer, make_train_step
from repro.training.optimizer import OptConfig, init_opt_state, lr_at
import jax.numpy as jnp

# every test here runs a real (small) training loop: 12-20 s apiece
pytestmark = pytest.mark.slow


def _trainer(tmp_path, steps=30, compress=False, seed=0, sparse=True):
    cfg = registry.get_smoke("smollm-360m", sparse=sparse).replace(
        num_layers=2, vocab_size=64
    )
    data = SyntheticLM(64, 32, 4, seed=seed)
    opt = OptConfig(
        lr=1e-2, warmup_steps=2, total_steps=steps, compress_grads=compress
    )
    return Trainer(
        cfg, opt, data, make_local_mesh(),
        TrainConfig(steps=steps, ckpt_dir=str(tmp_path), ckpt_every=10,
                    log_every=1000),
    )


def test_loss_decreases(tmp_path):
    t = _trainer(tmp_path / "a", steps=30)
    hist = t.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_checkpoint_resume_exact(tmp_path):
    d = tmp_path / "b"
    t1 = _trainer(d, steps=20)
    t1.run(10)
    t1.checkpoint()
    loss_cont = t1.run(5)[-1]["loss"]
    # new trainer restores from step 10 and must follow the same trajectory
    t2 = _trainer(d, steps=20)
    assert t2.step == 10
    loss_resumed = t2.run(5)[-1]["loss"]
    assert abs(loss_cont - loss_resumed) < 1e-3


def test_preemption_checkpoints(tmp_path):
    d = tmp_path / "c"
    t = _trainer(d, steps=50)
    t.run(3)
    t._on_preempt(signal.SIGTERM, None)
    t.run(10)  # should stop immediately and checkpoint
    from repro.training import checkpoint as ck
    assert ck.latest_step(str(d)) == 3


def test_straggler_watchdog(tmp_path):
    events = []
    t = _trainer(tmp_path / "d", steps=5)
    t._straggler_hook = lambda s, dt, ew: events.append((s, dt, ew))
    t._ewma = 1e-9  # force every step to look like a straggler
    t.run(2)
    assert t.straggler_events >= 1


def test_compressed_grads_still_converge(tmp_path):
    t = _trainer(tmp_path / "e", steps=30, compress=True)
    hist = t.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.15, (first, last)


def test_microbatched_step_matches_full():
    """Gradient accumulation must give (numerically close) identical
    updates to the single-batch step."""
    cfg = registry.get_smoke("qwen3-1.7b").replace(num_layers=2, vocab_size=64)
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    from repro.models import transformer as T
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": init_opt_state(opt, params)}
    data = SyntheticLM(64, 16, 8, seed=0)
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    s1 = make_train_step(cfg, opt, microbatches=1)
    s4 = make_train_step(cfg, opt, microbatches=4)
    (st1, m1) = s1(jax.tree.map(lambda x: x, state), batch)
    (st4, m4) = s4(state, batch)
    l1 = jax.tree.leaves(st1["params"])
    l4 = jax.tree.leaves(st4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-3, atol=5e-3,
        )


def test_lr_schedule():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(lr_at(opt, jnp.asarray(0))) < 0.2
    assert abs(float(lr_at(opt, jnp.asarray(10))) - 1.0) < 0.1
    assert float(lr_at(opt, jnp.asarray(110))) <= 0.11
